//! Per-figure experiment runners.
//!
//! Each `figN_*` function runs the simulated experiments behind one
//! evaluation figure and returns plain rows; the `cargo bench` targets
//! print them and write CSVs. Scale and sweep lists are parameters so
//! benches can trade fidelity for speed (`DD_SCALE`, `DD_TPN` env vars).

use crate::analysis::model;
use crate::config::{presets, Config};
use crate::coordinator::task::{Task, TaskId, TaskKind};
use crate::driver::sim::{SimDriver, SimWorkloadSpec};
use crate::driver::RunOutcome;
use crate::index::IndexBackend;
use crate::provisioner::AllocationPolicy;
use crate::scheduler::DispatchPolicy;
use crate::storage::object::{Catalog, DataFormat, ObjectId};
use crate::workloads::astro::{self, WorkloadRow};
use crate::workloads::bursty::{self, BurstSpec, DemandShape};
use crate::workloads::microbench::{self, MbConfig};

/// Environment-tunable workload scale for the astro sims (fraction of the
/// full Table 2 row; default keeps bench runtimes in seconds — set
/// `DD_SCALE=1.0` for the paper's full 100K+-task workloads).
pub fn env_scale() -> f64 {
    std::env::var("DD_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1)
}

/// Environment-tunable tasks-per-node for the micro-benchmarks.
pub fn env_tpn() -> usize {
    std::env::var("DD_TPN")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8)
}

// ------------------------------------------------------------------ Fig 2

/// One measured point of the Figure 2 companion: a real scheduled run
/// under one index backend.
#[derive(Debug, Clone)]
pub struct IndexBackendPoint {
    /// Backend label ("central" / "chord").
    pub backend: &'static str,
    /// Executor nodes (and Chord overlay size).
    pub nodes: usize,
    /// Tasks completed.
    pub tasks: u64,
    /// Simulated makespan, seconds.
    pub makespan_s: f64,
    /// Index lookups charged at dispatch time.
    pub index_lookups: u64,
    /// Overlay routing hops behind those lookups.
    pub index_hops: u64,
    /// Total simulated index latency charged, seconds.
    pub index_cost_s: f64,
    /// Mean hops per lookup (0 on the centralized backend).
    pub mean_hops: f64,
    /// Index cost as a fraction of the makespan.
    pub cost_fraction: f64,
}

/// Figure 2 (measured companion): run the *same* data-aware workload
/// through the real dispatch path under the centralized and the Chord
/// index and report what the index actually cost each run.
///
/// The analytic Figure 2 curves answer "when would a distributed index's
/// aggregate throughput catch up?"; this answers the operational
/// question behind them — what a scheduled run pays per backend today.
/// Placement is backend-invariant (see `crate::index`), so any makespan
/// delta is pure index cost.
pub fn fig2_measured(nodes_list: &[usize], tasks_per_node: usize) -> Vec<IndexBackendPoint> {
    let mut rows = Vec::new();
    for &nodes in nodes_list {
        for backend in [IndexBackend::Central, IndexBackend::Chord] {
            let mut cfg = Config::with_nodes(nodes);
            cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
            cfg.index.backend = backend;
            // Every object requested repeatedly with spaced arrivals, so
            // the index is consulted against warm state on every
            // dispatch (the regime §3.2.3 budgets for).
            let objects = 2 * nodes as u64;
            let total = (nodes * tasks_per_node.max(1)) as u64;
            let mut catalog = Catalog::new();
            for i in 0..objects {
                catalog.insert(ObjectId(i), crate::util::units::MB);
            }
            let tasks: Vec<(f64, Task)> = (0..total)
                .map(|i| {
                    (
                        i as f64 * 0.01,
                        Task::with_inputs(TaskId(i), vec![ObjectId(i % objects)]),
                    )
                })
                .collect();
            let out = SimDriver::new(cfg, SimWorkloadSpec::new(tasks), catalog).run();
            let m = &out.metrics;
            rows.push(IndexBackendPoint {
                backend: backend.label(),
                nodes,
                tasks: m.tasks_done,
                makespan_s: out.makespan_s,
                index_lookups: m.index_lookups,
                index_hops: m.index_hops,
                index_cost_s: m.index_cost_s,
                mean_hops: if m.index_lookups > 0 {
                    m.index_hops as f64 / m.index_lookups as f64
                } else {
                    0.0
                },
                cost_fraction: if out.makespan_s > 0.0 {
                    m.index_cost_s / out.makespan_s
                } else {
                    0.0
                },
            });
        }
    }
    rows
}

/// Print the measured Figure 2 companion table and write its CSV under
/// `dir`. Shared by the `fig2_index` bench and `falkon sweep --figure 2`
/// so the schema cannot drift. Returns the CSV path.
pub fn emit_fig2_measured(
    rows: &[IndexBackendPoint],
    dir: &std::path::Path,
) -> std::io::Result<std::path::PathBuf> {
    use crate::util::csv::CsvWriter;
    let mut csv = CsvWriter::new(
        dir.join("fig2_index_measured.csv"),
        &[
            "backend",
            "nodes",
            "tasks",
            "makespan_s",
            "index_lookups",
            "index_hops",
            "mean_hops",
            "index_cost_s",
            "cost_fraction",
        ],
    );
    println!(
        "{:<9} {:>6} {:>7} {:>12} {:>9} {:>7} {:>8} {:>13} {:>9}",
        "backend", "nodes", "tasks", "makespan", "lookups", "hops", "hops/op", "index cost", "cost%"
    );
    for r in rows {
        println!(
            "{:<9} {:>6} {:>7} {:>11.3}s {:>9} {:>7} {:>8.2} {:>12.6}s {:>8.4}%",
            r.backend,
            r.nodes,
            r.tasks,
            r.makespan_s,
            r.index_lookups,
            r.index_hops,
            r.mean_hops,
            r.index_cost_s,
            r.cost_fraction * 100.0
        );
        csv.rowf(&[
            &r.backend,
            &r.nodes,
            &r.tasks,
            &r.makespan_s,
            &r.index_lookups,
            &r.index_hops,
            &r.mean_hops,
            &r.index_cost_s,
            &r.cost_fraction,
        ]);
    }
    csv.finish()
}

// -------------------------------------------------------------- DRP figure

/// One measured point of the demand-response (DRP) figure: a bursty
/// workload scheduled end-to-end under one allocation policy with the
/// executor pool elastic.
#[derive(Debug, Clone)]
pub struct DrpPoint {
    /// Allocation-policy label ("one-at-a-time" / "all-at-once" /
    /// "adaptive").
    pub policy: &'static str,
    /// Tasks completed.
    pub tasks: u64,
    /// Simulated makespan, seconds.
    pub makespan_s: f64,
    /// Task throughput over the experiment span, tasks/s.
    pub tasks_per_s: f64,
    /// Largest pool the run reached.
    pub peak_executors: usize,
    /// Pool ceiling in force.
    pub max_executors: usize,
    /// Allocation requests sent to the cluster.
    pub alloc_requests: u64,
    /// Executors that joined mid-run.
    pub executors_joined: u64,
    /// Executors released mid-run.
    pub executors_released: u64,
    /// Executor-seconds spent fully idle while allocated.
    pub idle_exec_s: f64,
    /// Executor-seconds lost to allocation latency (requested, unusable).
    pub alloc_wait_s: f64,
    /// Local cache-hit ratio over the whole run.
    pub hit_ratio: f64,
    /// The full outcome (pool timeline included), for deeper analysis.
    pub outcome: RunOutcome,
}

/// The DRP figure: the same square-burst workload (two bursts separated
/// by a lull longer than the idle-release timeout) scheduled through the
/// real dispatch path under each of the three §3.1 allocation policies,
/// with the pool elastic end-to-end. This is the dynamic-provisioning
/// analog of `fig2_measured`: policies are compared on measured runs, not
/// closed-form curves — throughput vs the executor-seconds wasted idle
/// and the executor-seconds lost to allocation latency.
pub fn fig_drp(nodes: usize, tasks: u64) -> Vec<DrpPoint> {
    let nodes = nodes.max(2);
    let tasks = tasks.max(16);
    // Two bursts: the burst length carries half the tasks at a rate that
    // wants roughly the whole cluster; the lull comfortably exceeds the
    // idle-release timeout so every policy faces a shrink decision.
    let period_s = 200.0;
    let duty = 0.3;
    let peak_rate = tasks as f64 / (2.0 * duty * period_s);
    let spec = BurstSpec {
        shape: DemandShape::Square,
        tasks,
        objects: (tasks / 4).max(8),
        object_bytes: crate::util::units::MB,
        period_s,
        base_rate: 0.0,
        peak_rate,
        duty,
        task_cpu_s: 2.0,
    };
    let mut rows = Vec::new();
    for policy in [
        AllocationPolicy::OneAtATime,
        AllocationPolicy::Adaptive,
        AllocationPolicy::AllAtOnce,
    ] {
        let mut cfg = Config::with_nodes(nodes);
        cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
        cfg.provisioner.enabled = true;
        cfg.provisioner.policy = policy;
        cfg.provisioner.min_executors = 1;
        cfg.provisioner.max_executors = nodes;
        cfg.provisioner.allocation_latency_s = 30.0;
        cfg.provisioner.idle_release_s = 20.0;
        cfg.provisioner.poll_interval_s = 2.0;
        cfg.provisioner.queue_per_executor = 2;
        let w = bursty::generate(&spec, 20080611);
        let out = SimDriver::new(cfg, w.spec, w.catalog).run();
        let m = &out.metrics;
        rows.push(DrpPoint {
            policy: policy.label(),
            tasks: m.tasks_done,
            makespan_s: out.makespan_s,
            tasks_per_s: m.task_rate(),
            peak_executors: m.peak_executors,
            max_executors: nodes,
            alloc_requests: m.alloc_requests,
            executors_joined: m.executors_joined,
            executors_released: m.executors_released,
            idle_exec_s: m.idle_exec_s,
            alloc_wait_s: m.alloc_wait_s,
            hit_ratio: m.local_hit_ratio(),
            outcome: out,
        });
    }
    rows
}

/// Print the DRP comparison table and write the summary + per-tick
/// timeline CSVs under `dir`. One emitter shared by the `fig_drp` bench
/// and `falkon sweep --figure drp`, so the table format and CSV schema
/// cannot drift. Returns the two CSV paths.
pub fn emit_drp(
    rows: &[DrpPoint],
    dir: &std::path::Path,
) -> std::io::Result<(std::path::PathBuf, std::path::PathBuf)> {
    use crate::util::csv::CsvWriter;
    println!(
        "{:<14} {:>6} {:>11} {:>9} {:>10} {:>7} {:>7} {:>9} {:>12} {:>13} {:>7}",
        "policy",
        "tasks",
        "makespan",
        "tasks/s",
        "peak-pool",
        "allocs",
        "joined",
        "released",
        "idle-exec-s",
        "alloc-wait-s",
        "hit%"
    );
    let mut csv = CsvWriter::new(
        dir.join("fig_drp.csv"),
        &[
            "policy",
            "tasks",
            "makespan_s",
            "tasks_per_s",
            "peak_executors",
            "max_executors",
            "alloc_requests",
            "executors_joined",
            "executors_released",
            "idle_exec_s",
            "alloc_wait_s",
            "hit_ratio",
        ],
    );
    let mut tcsv = CsvWriter::new(
        dir.join("fig_drp_timeline.csv"),
        &[
            "policy",
            "t_s",
            "allocated",
            "pending",
            "queued",
            "window_hit_ratio",
            "replicas",
            "staging_deferred",
        ],
    );
    for r in rows {
        println!(
            "{:<14} {:>6} {:>10.1}s {:>9.2} {:>7}/{:<2} {:>7} {:>7} {:>9} {:>12.0} {:>13.0} {:>6.1}%",
            r.policy,
            r.tasks,
            r.makespan_s,
            r.tasks_per_s,
            r.peak_executors,
            r.max_executors,
            r.alloc_requests,
            r.executors_joined,
            r.executors_released,
            r.idle_exec_s,
            r.alloc_wait_s,
            r.hit_ratio * 100.0
        );
        csv.rowf(&[
            &r.policy,
            &r.tasks,
            &r.makespan_s,
            &r.tasks_per_s,
            &r.peak_executors,
            &r.max_executors,
            &r.alloc_requests,
            &r.executors_joined,
            &r.executors_released,
            &r.idle_exec_s,
            &r.alloc_wait_s,
            &r.hit_ratio,
        ]);
        let mut prev: Option<crate::coordinator::metrics::PoolSample> = None;
        for s in &r.outcome.metrics.pool_timeline {
            let w = prev.map(|p| s.window_hit_ratio(&p)).unwrap_or(0.0);
            tcsv.rowf(&[
                &r.policy,
                &s.t,
                &s.allocated,
                &s.pending,
                &s.queued,
                &w,
                &s.replicas,
                &s.staging_deferred,
            ]);
            prev = Some(*s);
        }
    }
    Ok((csv.finish()?, tcsv.finish()?))
}

// -------------------------------------------------------- Diffusion figure

/// One measured point of the data-diffusion figure: the same bursty
/// hot-set workload scheduled end-to-end at one cache-node count, with
/// demand-driven replication on or off.
#[derive(Debug, Clone)]
pub struct DiffusionPoint {
    /// "replication-on" / "replication-off".
    pub mode: &'static str,
    /// Cache-node ceiling (elastic pool max).
    pub nodes: usize,
    /// Tasks completed.
    pub tasks: u64,
    /// Simulated makespan, seconds.
    pub makespan_s: f64,
    /// Aggregate read throughput over the span, bits/sec (local + c2c +
    /// GPFS — the paper's linear-I/O-scaling metric).
    pub read_bps: f64,
    /// Fraction of input resolutions served by the executor's own cache.
    pub local_hit_ratio: f64,
    /// Fraction served by any cached copy (local or peer).
    pub any_hit_ratio: f64,
    /// Replicas the manager staged into caches.
    pub replicas_created: u64,
    /// Bytes shipped by staging transfers.
    pub replica_bytes_staged: u64,
    /// Local hits served by staged replicas.
    pub replica_hits: u64,
    /// Peer-cache resolutions (paid on the task critical path).
    pub peer_hits: u64,
    /// Persistent-storage resolutions.
    pub gpfs_misses: u64,
    /// Executors that joined mid-run (the churn replication heals).
    pub executors_joined: u64,
    /// The full outcome (pool timeline included), for deeper analysis.
    pub outcome: RunOutcome,
}

/// The data-diffusion figure: aggregate read throughput and hit ratio
/// vs. cache-node count, with demand-driven replication on and off.
///
/// The workload is the DRP shape — two square bursts over a small hot
/// object set, separated by a lull longer than the idle-release timeout,
/// on an elastic pool — because that is the regime where the paper's
/// namesake mechanism must earn its keep: burst one warms the pool,
/// the lull shrinks it (released leases lose their caches), and burst
/// two re-grows it from cold nodes. Without replication every re-joined
/// executor pays one peer/GPFS miss per hot object on the task critical
/// path; with it, joiners are pre-staged with the hottest objects and
/// sustained demand keeps replica sets wide, so tasks find data locally
/// and aggregate read bandwidth scales with the node count instead of
/// hammering the surviving holders.
pub fn fig_diffusion(nodes_list: &[usize], tasks_per_node: usize) -> Vec<DiffusionPoint> {
    let mut rows = Vec::new();
    for &nodes in nodes_list {
        let nodes = nodes.max(2);
        let tasks = (nodes * tasks_per_node.max(4)) as u64;
        let spec = BurstSpec {
            shape: DemandShape::Square,
            tasks,
            // Hot set smaller than the pool: contention on holders is
            // what replication relieves.
            objects: (nodes as u64 / 2).max(4),
            object_bytes: crate::util::units::MB,
            period_s: 200.0,
            base_rate: 0.0,
            // Two 60 s bursts carry the whole workload.
            peak_rate: tasks as f64 / 120.0,
            duty: 0.3,
            task_cpu_s: 2.0,
        };
        for on in [false, true] {
            let mut cfg = Config::with_nodes(nodes);
            cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
            cfg.provisioner.enabled = true;
            cfg.provisioner.policy = AllocationPolicy::Adaptive;
            cfg.provisioner.min_executors = 1;
            cfg.provisioner.max_executors = nodes;
            cfg.provisioner.allocation_latency_s = 30.0;
            cfg.provisioner.idle_release_s = 20.0;
            cfg.provisioner.poll_interval_s = 2.0;
            cfg.provisioner.queue_per_executor = 2;
            if on {
                cfg.replication.enabled = true;
                cfg.replication.max_replicas = nodes;
                // Per-object lookup rate during a burst is peak_rate /
                // objects ≈ 0.4–1.6 per 2 s evaluation at these scales;
                // the threshold sits below the burst floor so demand
                // replication engages at every node count, and the EWMA
                // decays through it in the lull (back-off).
                cfg.replication.demand_threshold = 0.3;
                cfg.replication.ewma_alpha = 0.5;
                cfg.replication.evaluate_interval_s = 2.0;
                cfg.replication.prestage_top_k = 8;
                cfg.replication.max_inflight = nodes.max(8);
            }
            let w = bursty::generate(&spec, 20080612);
            let out = SimDriver::new(cfg, w.spec, w.catalog).run();
            let m = &out.metrics;
            rows.push(DiffusionPoint {
                mode: if on { "replication-on" } else { "replication-off" },
                nodes,
                tasks: m.tasks_done,
                makespan_s: out.makespan_s,
                read_bps: m.read_throughput_bps(),
                local_hit_ratio: m.local_hit_ratio(),
                any_hit_ratio: m.any_hit_ratio(),
                replicas_created: m.replicas_created,
                replica_bytes_staged: m.replica_bytes_staged,
                replica_hits: m.replica_hits,
                peer_hits: m.peer_hits,
                gpfs_misses: m.gpfs_misses,
                executors_joined: m.executors_joined,
                outcome: out,
            });
        }
    }
    rows
}

/// Print the diffusion comparison table and write its CSV under `dir`.
/// Shared by the `fig_diffusion` bench and `falkon sweep --figure
/// diffusion`. Returns the CSV path.
pub fn emit_diffusion(
    rows: &[DiffusionPoint],
    dir: &std::path::Path,
) -> std::io::Result<std::path::PathBuf> {
    use crate::util::csv::CsvWriter;
    println!(
        "{:<16} {:>6} {:>6} {:>11} {:>11} {:>7} {:>7} {:>9} {:>13} {:>9} {:>7} {:>7}",
        "mode",
        "nodes",
        "tasks",
        "makespan",
        "read-bw",
        "local%",
        "any%",
        "replicas",
        "staged-bytes",
        "rep-hits",
        "peer",
        "gpfs"
    );
    let mut csv = CsvWriter::new(
        dir.join("fig_diffusion.csv"),
        &[
            "mode",
            "nodes",
            "tasks",
            "makespan_s",
            "read_bps",
            "local_hit_ratio",
            "any_hit_ratio",
            "replicas_created",
            "replica_bytes_staged",
            "replica_hits",
            "peer_hits",
            "gpfs_misses",
            "executors_joined",
        ],
    );
    for r in rows {
        println!(
            "{:<16} {:>6} {:>6} {:>10.1}s {:>11} {:>6.1}% {:>6.1}% {:>9} {:>13} {:>9} {:>7} {:>7}",
            r.mode,
            r.nodes,
            r.tasks,
            r.makespan_s,
            crate::util::units::fmt_bps(r.read_bps),
            r.local_hit_ratio * 100.0,
            r.any_hit_ratio * 100.0,
            r.replicas_created,
            r.replica_bytes_staged,
            r.replica_hits,
            r.peer_hits,
            r.gpfs_misses
        );
        csv.rowf(&[
            &r.mode,
            &r.nodes,
            &r.tasks,
            &r.makespan_s,
            &r.read_bps,
            &r.local_hit_ratio,
            &r.any_hit_ratio,
            &r.replicas_created,
            &r.replica_bytes_staged,
            &r.replica_hits,
            &r.peer_hits,
            &r.gpfs_misses,
            &r.executors_joined,
        ]);
    }
    csv.finish()
}

// -------------------------------------------------------------- QoS figure

/// One measured point of the QoS figure: the same saturating staging
/// workload under one transfer share policy.
#[derive(Debug, Clone)]
pub struct QosPoint {
    /// Share-policy axis: "off" (no metering), "binary" (start-time
    /// deferral), "weighted" (per-class fair shares).
    pub mode: &'static str,
    /// Executor count.
    pub nodes: usize,
    /// Tasks completed.
    pub tasks: u64,
    /// Simulated makespan, seconds.
    pub makespan_s: f64,
    /// p50 of foreground task latency (submit → complete), seconds.
    pub p50_task_s: f64,
    /// p90 of foreground task latency, seconds.
    pub p90_task_s: f64,
    /// p99 of foreground task latency, seconds — the figure's headline
    /// metric.
    pub p99_task_s: f64,
    /// Mean foreground task latency, seconds.
    pub mean_task_s: f64,
    /// Fraction of input resolutions served by the executor's own cache.
    pub local_hit_ratio: f64,
    /// Replicas the manager staged into caches (replication must still
    /// converge under admission control).
    pub replicas_created: u64,
    /// Bytes shipped by staging transfers.
    pub replica_bytes_staged: u64,
    /// Staging transfers deferred by admission control.
    pub staging_deferred: u64,
    /// Index control-plane stabilization messages.
    pub stabilization_msgs: u64,
    /// Bytes moved per transfer class [foreground, staging, prestage].
    pub class_bytes: [u64; 3],
    /// Mean achieved staging rate, bits/sec (weighted mode throttles
    /// this; binary stop-starts it).
    pub staging_rate_bps: f64,
    /// Peer-cache resolutions (paid on the task critical path).
    pub peer_hits: u64,
    /// Persistent-storage resolutions.
    pub gpfs_misses: u64,
    /// The full outcome, for deeper analysis.
    pub outcome: RunOutcome,
}

/// The QoS figure: foreground task latency under saturating staging
/// load across the three-way share-policy axis — off / binary /
/// weighted.
///
/// The workload is bursts of `nodes` tasks every 2 s over a hot object
/// set that lives entirely on executor 0 at t=0, so every burst queues
/// up on node 0's egress (disk-read + NIC) — exactly the resource
/// replication staging also wants, since node 0 is the holder the
/// manager copies from. `off` (binary policy, budget 1.0) never meters:
/// up to `max_inflight` staging flows share node 0's disk 1:1 with the
/// burst's foreground fetches and the burst tail pays for it in
/// latency. `binary` (budget 0.35) defers stagings submitted mid-burst
/// and drains them stop-start in the inter-burst gaps — the tail
/// tightens but staging throughput becomes bursty. `weighted` (budget
/// 1.0, default class weights) admits every staging immediately but
/// its flows run at weight 0.25 against foreground's 1.0 — foreground
/// keeps p99 at binary's level while staging moves continuously, so
/// bytes staged never fall below binary's stop-start schedule.
pub fn fig_qos(nodes_list: &[usize], bursts: usize) -> Vec<QosPoint> {
    use crate::transfer::SharePolicyKind;
    let modes: [(&'static str, SharePolicyKind, f64); 3] = [
        ("off", SharePolicyKind::Binary, 1.0),
        ("binary", SharePolicyKind::Binary, 0.35),
        ("weighted", SharePolicyKind::Weighted, 1.0),
    ];
    let mut rows = Vec::new();
    for &nodes in nodes_list {
        let nodes = nodes.max(2);
        let objects = (nodes as u64).max(4);
        let obj_bytes = 4 * crate::util::units::MB;
        let tasks = nodes as u64 * bursts.max(4) as u64;
        for (mode, policy, budget) in modes {
            let mut cfg = Config::with_nodes(nodes);
            cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
            cfg.replication.enabled = true;
            cfg.replication.max_replicas = nodes;
            // Each object is requested about once per 2 s burst period;
            // the threshold sits well under that so staging pressure is
            // sustained ("saturating staging load"), and the evaluation
            // cadence is offset from the burst period so evaluations land
            // both mid-burst (deferrals) and mid-gap (admissions).
            cfg.replication.demand_threshold = 0.2;
            cfg.replication.ewma_alpha = 0.5;
            cfg.replication.evaluate_interval_s = 0.55;
            cfg.replication.max_inflight = 2 * nodes;
            cfg.transfer.share_policy = policy;
            cfg.transfer.staging_budget = budget;
            let mut catalog = Catalog::new();
            for i in 0..objects {
                catalog.insert(ObjectId(i), obj_bytes);
            }
            let task_list: Vec<(f64, Task)> = (0..tasks)
                .map(|i| {
                    let burst = i / nodes as u64;
                    let slot = i % nodes as u64;
                    let mut t = Task::with_inputs(TaskId(i), vec![ObjectId(i % objects)]);
                    t.kind = TaskKind::Synthetic { cpu_s: 0.2 };
                    (burst as f64 * 2.0 + slot as f64 * 0.005, t)
                })
                .collect();
            let mut spec = SimWorkloadSpec::new(task_list);
            spec.prewarm = (0..objects).map(|o| (0usize, ObjectId(o))).collect();
            let out = SimDriver::new(cfg, spec, catalog).run();
            let mut m = out.metrics.clone();
            rows.push(QosPoint {
                mode,
                nodes,
                tasks: m.tasks_done,
                makespan_s: out.makespan_s,
                p50_task_s: m.task_latency_p50(),
                p90_task_s: m.task_latency_p90(),
                p99_task_s: m.task_latency_p99(),
                mean_task_s: m.task_latency.mean(),
                local_hit_ratio: m.local_hit_ratio(),
                replicas_created: m.replicas_created,
                replica_bytes_staged: m.replica_bytes_staged,
                staging_deferred: m.staging_deferred,
                stabilization_msgs: m.stabilization_msgs,
                class_bytes: m.class_bytes,
                staging_rate_bps: m.class_mean_rate_bps(crate::transfer::TransferClass::Staging),
                peer_hits: m.peer_hits,
                gpfs_misses: m.gpfs_misses,
                outcome: out,
            });
        }
    }
    rows
}

/// Print the QoS comparison table and write its CSV under `dir`. Shared
/// by the `fig_qos` bench and `falkon sweep --figure qos`. Returns the
/// CSV path.
pub fn emit_qos(
    rows: &[QosPoint],
    dir: &std::path::Path,
) -> std::io::Result<std::path::PathBuf> {
    use crate::util::csv::CsvWriter;
    println!(
        "{:<10} {:>6} {:>6} {:>11} {:>9} {:>9} {:>9} {:>10} {:>7} {:>9} {:>9} {:>13} {:>11}",
        "mode",
        "nodes",
        "tasks",
        "makespan",
        "p50-task",
        "p90-task",
        "p99-task",
        "mean-task",
        "local%",
        "replicas",
        "deferred",
        "staged-bytes",
        "stage-rate"
    );
    let mut csv = CsvWriter::new(
        dir.join("fig_qos.csv"),
        &[
            "mode",
            "nodes",
            "tasks",
            "makespan_s",
            "p50_task_s",
            "p90_task_s",
            "p99_task_s",
            "mean_task_s",
            "local_hit_ratio",
            "replicas_created",
            "replica_bytes_staged",
            "staging_deferred",
            "stabilization_msgs",
            "class_fg_bytes",
            "class_staging_bytes",
            "class_prestage_bytes",
            "staging_rate_bps",
            "peer_hits",
            "gpfs_misses",
        ],
    );
    for r in rows {
        println!(
            "{:<10} {:>6} {:>6} {:>10.1}s {:>8.3}s {:>8.3}s {:>8.3}s {:>9.3}s {:>6.1}% {:>9} {:>9} {:>13} {:>11}",
            r.mode,
            r.nodes,
            r.tasks,
            r.makespan_s,
            r.p50_task_s,
            r.p90_task_s,
            r.p99_task_s,
            r.mean_task_s,
            r.local_hit_ratio * 100.0,
            r.replicas_created,
            r.staging_deferred,
            r.replica_bytes_staged,
            crate::util::units::fmt_bps(r.staging_rate_bps)
        );
        csv.rowf(&[
            &r.mode,
            &r.nodes,
            &r.tasks,
            &r.makespan_s,
            &r.p50_task_s,
            &r.p90_task_s,
            &r.p99_task_s,
            &r.mean_task_s,
            &r.local_hit_ratio,
            &r.replicas_created,
            &r.replica_bytes_staged,
            &r.staging_deferred,
            &r.stabilization_msgs,
            &r.class_bytes[0],
            &r.class_bytes[1],
            &r.class_bytes[2],
            &r.staging_rate_bps,
            &r.peer_hits,
            &r.gpfs_misses,
        ]);
    }
    csv.finish()
}

// ------------------------------------------------- Shard-scaling figure

/// One measured point of the shard-scaling figure: the same queued
/// workload drained through the dispatch core at one shard count.
#[derive(Debug, Clone)]
pub struct ShardPoint {
    /// Dispatcher shard count.
    pub shards: usize,
    /// Tasks dispatched and retired.
    pub tasks: u64,
    /// Wall-clock seconds the drain took.
    pub wall_s: f64,
    /// Dispatch throughput, tasks/s (the §3.1 ~3800 tasks/s axis).
    pub tasks_per_s: f64,
    /// Mean decision latency per task, microseconds (§3.2.3 budget).
    pub decision_us: f64,
    /// Throughput relative to the sweep's first shard count.
    pub speedup: f64,
    /// Cross-shard steal batches executed during the drain.
    pub steals: u64,
    /// Tasks moved by stealing.
    pub stolen_tasks: u64,
    /// Non-empty dispatch batches emitted.
    pub batches: u64,
}

/// The shard-scaling figure: dispatch throughput vs dispatcher shard
/// count over one bursty hot-set workload, measured through
/// [`crate::coordinator::sharded::ShardedCore::drain_all`] (pure
/// decision + queue throughput: tasks
/// retire instantly, so no I/O physics dilutes the axis). Each shard's
/// index slice is prewarmed with the objects it owns, cached on its own
/// executors, so the window scan scores real locations — the regime
/// where the single-loop dispatcher's decision rate is the ceiling the
/// paper's §3.1/§3.2.3 budgets describe.
pub fn fig_shard_scaling(shards_list: &[usize], tasks: u64, executors: usize) -> Vec<ShardPoint> {
    use crate::cache::store::CacheEvent;
    use crate::config::SchedulerConfig;
    use crate::coordinator::sharded::ShardedCore;

    let tasks = tasks.max(64);
    let executors = executors.max(2);
    // Bursty arrivals over a hot object set: deep ready queues at the
    // peaks, exactly the backlog shape batched dispatch amortizes.
    let spec = BurstSpec {
        shape: DemandShape::Square,
        tasks,
        objects: (tasks / 8).max(16),
        object_bytes: crate::util::units::MB,
        period_s: 60.0,
        base_rate: 0.0,
        peak_rate: tasks as f64 / 36.0,
        duty: 0.3,
        task_cpu_s: 0.0,
    };
    let w = bursty::generate(&spec, 20080613);
    let task_list: Vec<Task> = w.spec.tasks.iter().map(|(_, t)| t.clone()).collect();
    let mut rows: Vec<ShardPoint> = Vec::new();
    let mut base_rate = 0.0f64;
    for &shards in shards_list {
        let shards = shards.max(1);
        let cfg = SchedulerConfig {
            policy: DispatchPolicy::MaxComputeUtil,
            window: 64,
            ..SchedulerConfig::default()
        };
        let mut core = ShardedCore::new(&cfg, w.catalog.clone(), shards);
        for e in 0..executors {
            core.register_executor_with(e, 2);
        }
        // Warm each shard's index slice: every object cached on one
        // executor of its owning shard (e ≡ shard (mod shards)), so
        // tasks find their dominant input local to the shard that
        // schedules them.
        let per = (executors / shards).max(1);
        for obj in w.catalog.ids() {
            let s = core.shard_of_object(obj);
            let e = s + shards * (obj.0 as usize % per);
            if e < executors {
                core.apply_cache_events(e, &[CacheEvent::Inserted(obj)]);
            }
        }
        for t in task_list.clone() {
            core.submit(t);
        }
        let t0 = std::time::Instant::now();
        let retired = core.drain_all();
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        let stats = core.shard_stats();
        let rate = retired as f64 / wall;
        if rows.is_empty() {
            base_rate = rate;
        }
        rows.push(ShardPoint {
            shards,
            tasks: retired,
            wall_s: wall,
            tasks_per_s: rate,
            decision_us: wall / retired.max(1) as f64 * 1e6,
            speedup: rate / base_rate.max(1e-12),
            steals: stats.steals,
            stolen_tasks: stats.stolen_tasks,
            batches: stats.batches,
        });
    }
    rows
}

/// Print the shard-scaling table and write its CSV under `dir`. Shared
/// by the `dispatch_throughput` bench and `falkon sweep --figure
/// shards`. Returns the CSV path.
pub fn emit_shard_scaling(
    rows: &[ShardPoint],
    dir: &std::path::Path,
) -> std::io::Result<std::path::PathBuf> {
    use crate::util::csv::CsvWriter;
    let mut csv = CsvWriter::new(
        dir.join("fig_shard_scaling.csv"),
        &[
            "shards",
            "tasks",
            "wall_s",
            "tasks_per_s",
            "decision_us",
            "speedup",
            "steals",
            "stolen_tasks",
            "batches",
        ],
    );
    println!(
        "{:<7} {:>8} {:>10} {:>12} {:>12} {:>8} {:>7} {:>7} {:>8}",
        "shards",
        "tasks",
        "wall",
        "tasks/s",
        "decision",
        "speedup",
        "steals",
        "stolen",
        "batches"
    );
    for r in rows {
        println!(
            "{:<7} {:>8} {:>9.4}s {:>12.0} {:>10.2}us {:>7.2}x {:>7} {:>7} {:>8}",
            r.shards,
            r.tasks,
            r.wall_s,
            r.tasks_per_s,
            r.decision_us,
            r.speedup,
            r.steals,
            r.stolen_tasks,
            r.batches
        );
        csv.rowf(&[
            &r.shards,
            &r.tasks,
            &r.wall_s,
            &r.tasks_per_s,
            &r.decision_us,
            &r.speedup,
            &r.steals,
            &r.stolen_tasks,
            &r.batches,
        ]);
    }
    csv.finish()
}

// -------------------------------------------- Live shard-scaling figure

/// One measured point of the live dispatcher-scaling axis: the same
/// zero-I/O task batch pushed through the live driver's coordination
/// plane at one `--shards` count.
#[derive(Debug, Clone)]
pub struct LiveShardPoint {
    /// Dispatcher shard count (1 = the single coordinator loop).
    pub shards: usize,
    /// Tasks dispatched and retired through real executor threads.
    pub tasks: u64,
    /// Wall-clock seconds the run took.
    pub wall_s: f64,
    /// Live dispatch throughput, tasks/s.
    pub tasks_per_s: f64,
    /// Summed dispatcher-loop busy time across shard loops (0 at
    /// `shards = 1`, where the single loop does not meter itself).
    pub busy_s: f64,
    /// Cross-shard steal batches executed by the shard loops.
    pub steals: u64,
}

/// Measure live dispatch throughput at each shard count: real executor
/// threads, real channels, real coordination — but zero-input synthetic
/// tasks over an empty store, so no file I/O or compute dilutes the
/// dispatcher axis. This is the live-mode counterpart of
/// [`fig_shard_scaling`] (which measures the decision core alone), used
/// by the `dispatch_throughput` bench's `live-sharded@N` rows and the
/// `live_shard_equivalence` throughput gate.
pub fn fig_live_shard_scaling(
    shards_list: &[usize],
    tasks: u64,
    executors: usize,
) -> crate::error::Result<Vec<LiveShardPoint>> {
    use crate::driver::live::LiveCluster;
    use crate::storage::live::LiveStore;

    let executors = executors.max(1);
    let tasks = tasks.max(64);
    let base = std::env::temp_dir().join(format!("falkon-live-shards-{}", std::process::id()));
    let mut rows: Vec<LiveShardPoint> = Vec::new();
    for &shards in shards_list {
        let shards = shards.max(1);
        let dir = base.join(format!("s{shards}"));
        let _ = std::fs::remove_dir_all(&dir);
        let store = LiveStore::create(dir.join("gpfs"), DataFormat::Fit)?;
        let mut cfg = Config::with_nodes(executors);
        // FirstAvailable + inputless tasks: every report/dispatch
        // round-trip exercises the coordination plane and nothing else.
        cfg.scheduler.policy = DispatchPolicy::FirstAvailable;
        cfg.scheduler.tasks_per_cpu = 4;
        cfg.coordinator.shards = shards;
        let batch: Vec<Task> = (0..tasks)
            .map(|i| Task::with_inputs(TaskId(i), Vec::new()))
            .collect();
        let t0 = std::time::Instant::now();
        let out = LiveCluster::new(cfg, store, dir.join("work"), None).run(batch)?;
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        rows.push(LiveShardPoint {
            shards,
            tasks: out.metrics.tasks_done,
            wall_s: wall,
            tasks_per_s: out.metrics.tasks_done as f64 / wall,
            busy_s: out.metrics.dispatch_loop_busy_s,
            steals: out.metrics.dispatch_steals,
        });
        let _ = std::fs::remove_dir_all(&dir);
    }
    let _ = std::fs::remove_dir_all(&base);
    Ok(rows)
}

// ----------------------------------------------------- Simulator scale

/// One measured cell of the simulator-scalability figure: a full
/// data-aware run at one (executors × tasks) grid point.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    /// Executor nodes simulated.
    pub executors: usize,
    /// Federation sites the testbed was split into (1 = single cluster).
    pub sites: usize,
    /// Parallel-engine worker threads the cell ran at (capped at the
    /// site count inside the engine; 1 = serial).
    pub threads: usize,
    /// Tasks submitted (all must retire).
    pub tasks: u64,
    /// Discrete events the engine processed.
    pub events: u64,
    /// Simulated makespan, seconds.
    pub makespan_s: f64,
    /// Wall-clock seconds the run took.
    pub wall_s: f64,
    /// Engine throughput, events per wall-clock second — the axis that
    /// must degrade sub-linearly for extreme-scale runs to stay feasible.
    pub events_per_s: f64,
    /// Wall-clock speedup over the cell's first thread count (1.0 in
    /// the baseline row; timing-noisy — read trends, not digits).
    pub speedup: f64,
    /// Process peak RSS after the cell, MB (`VmHWM`; cumulative across
    /// the process, so run cells smallest-first — 0.0 off Linux).
    pub peak_rss_mb: f64,
}

/// Extract the `VmHWM` high-water mark (MB) from a
/// `/proc/self/status`-shaped string; 0.0 when the field is absent or
/// malformed (kernels without per-process HWM accounting omit it).
fn parse_vm_hwm(status: &str) -> f64 {
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb = rest.trim().trim_end_matches("kB").trim();
            return kb.parse::<f64>().unwrap_or(0.0) / 1024.0;
        }
    }
    0.0
}

/// Peak resident-set size of this process in MB, from
/// `/proc/self/status` `VmHWM` (0.0 where the file or the field is
/// unavailable — figures still emit, with a zero RSS column). A
/// high-water mark: it only grows, so grids should run their largest
/// cell last.
pub fn peak_rss_mb() -> f64 {
    match std::fs::read_to_string("/proc/self/status") {
        Ok(status) => parse_vm_hwm(&status),
        Err(_) => 0.0,
    }
}

/// The simulator-scalability figure: wall-clock, events/sec, and peak
/// RSS for full data-aware runs over an (executors × tasks) grid.
///
/// The workload is the scale-stressing shape, not the physics-stressing
/// one: one 1 MB object per executor, prewarmed locally, every task a
/// cache-local read on its home executor. Arrivals at 2 000 tasks/s keep
/// the dispatcher below its ~3 800/s ceiling, so the measured axis is
/// engine + flow-network throughput — the calendar event queue and the
/// incremental per-component refill — rather than queueing physics.
/// Cells run in the given order; pass grids smallest-first so the RSS
/// column reads as per-cell peaks (see [`peak_rss_mb`]).
///
/// `sites` splits each cell's testbed into federation sites (1 = the
/// classic single cluster) and `threads_list` sweeps the parallel
/// engine's worker count per cell; each row's speedup is its
/// wall-clock gain over the cell's *first* thread count, so pass the
/// baseline (usually 1) first.
pub fn fig_scale(
    executors_list: &[usize],
    tasks_list: &[u64],
    sites: usize,
    threads_list: &[usize],
) -> Vec<ScalePoint> {
    let threads_list = if threads_list.is_empty() { &[1][..] } else { threads_list };
    let mut rows = Vec::new();
    for &executors in executors_list {
        let executors = executors.max(2);
        for &tasks in tasks_list {
            let tasks = tasks.max(64);
            let mut base_wall = None;
            for &threads in threads_list {
                let threads = threads.max(1);
                let mut cfg = Config::with_nodes(executors);
                cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
                cfg.split_into_sites(sites);
                cfg.sim.threads = threads;
                let mut catalog = Catalog::new();
                for e in 0..executors {
                    catalog.insert(ObjectId(e as u64), crate::util::units::MB);
                }
                let task_list: Vec<(f64, Task)> = (0..tasks)
                    .map(|i| {
                        (
                            i as f64 * 0.0005,
                            Task::with_inputs(TaskId(i), vec![ObjectId(i % executors as u64)]),
                        )
                    })
                    .collect();
                let mut spec = SimWorkloadSpec::new(task_list);
                spec.prewarm = (0..executors).map(|e| (e, ObjectId(e as u64))).collect();
                let t0 = std::time::Instant::now();
                let out = SimDriver::new(cfg, spec, catalog).run();
                let wall = t0.elapsed().as_secs_f64().max(1e-9);
                let base = *base_wall.get_or_insert(wall);
                rows.push(ScalePoint {
                    executors,
                    sites: sites.max(1),
                    threads,
                    tasks: out.metrics.tasks_done,
                    events: out.events,
                    makespan_s: out.makespan_s,
                    wall_s: wall,
                    events_per_s: out.events as f64 / wall,
                    speedup: base / wall,
                    peak_rss_mb: peak_rss_mb(),
                });
            }
        }
    }
    rows
}

/// Print the simulator-scale table and write its CSV under `dir`. Shared
/// by the `fig_scale` bench and `falkon sweep --figure scale`. Returns
/// the CSV path.
pub fn emit_scale(
    rows: &[ScalePoint],
    dir: &std::path::Path,
) -> std::io::Result<std::path::PathBuf> {
    use crate::util::csv::CsvWriter;
    let mut csv = CsvWriter::new(
        dir.join("fig_scale.csv"),
        &[
            "executors",
            "sites",
            "threads",
            "tasks",
            "events",
            "makespan_s",
            "wall_s",
            "events_per_s",
            "speedup",
            "peak_rss_mb",
        ],
    );
    println!(
        "{:<10} {:>5} {:>7} {:>9} {:>10} {:>11} {:>10} {:>12} {:>7} {:>9}",
        "executors",
        "sites",
        "threads",
        "tasks",
        "events",
        "makespan",
        "wall",
        "events/s",
        "speedup",
        "rss"
    );
    for r in rows {
        println!(
            "{:<10} {:>5} {:>7} {:>9} {:>10} {:>10.1}s {:>9.3}s {:>12.0} {:>6.2}x {:>7.1}MB",
            r.executors,
            r.sites,
            r.threads,
            r.tasks,
            r.events,
            r.makespan_s,
            r.wall_s,
            r.events_per_s,
            r.speedup,
            r.peak_rss_mb
        );
        csv.rowf(&[
            &r.executors,
            &r.sites,
            &r.threads,
            &r.tasks,
            &r.events,
            &r.makespan_s,
            &r.wall_s,
            &r.events_per_s,
            &r.speedup,
            &r.peak_rss_mb,
        ]);
    }
    csv.finish()
}

// ----------------------------------------------------------- Federation

/// One cell of the federation sweep: one placement mode on one
/// (site count × WAN bandwidth × origin skew) configuration.
#[derive(Debug, Clone)]
pub struct FederationPoint {
    /// Member sites the testbed was split into.
    pub sites: usize,
    /// Parallel-engine worker threads the cell ran at (outcomes are
    /// thread-count invariant; only wall-clock changes).
    pub threads: usize,
    /// Per-site WAN uplink, Gbit/s (pairwise link = min of endpoints).
    pub wan_gbps: f64,
    /// Fraction of task origins pinned to the home site.
    pub skew: f64,
    /// Placement-policy label ("affinity" / "home" / "random").
    pub placement: &'static str,
    /// Tasks retired (all must drain).
    pub tasks: u64,
    /// Simulated makespan, seconds.
    pub makespan_s: f64,
    /// Bytes that crossed a WAN link (cross-site cache pulls + off-home
    /// GPFS traffic) — the cost axis affinity placement must win.
    pub wan_bytes: u64,
    /// Tasks placed at a site other than their origin.
    pub cross_site_tasks: u64,
    /// Cache-to-cache bytes (any distance).
    pub c2c_bytes: u64,
    /// Shared-filesystem read bytes.
    pub gpfs_bytes: u64,
}

/// The federation figure: ship-task vs ship-data across a (site count ×
/// WAN bandwidth × origin skew) grid, all three placement modes per
/// cell.
///
/// The workload gives data-aware placement something to follow: one
/// 32 MB object per executor, prewarmed in place, so the cache layout is
/// round-robin across sites; each task reads one object round-robin,
/// with origins drawn per the skew. Affinity ships tasks to the holding
/// site (paying only the dispatch hop); the always-home and random-site
/// baselines ship data instead, serializing on the WAN links — they must
/// lose on makespan AND WAN bytes whenever there is more than one site.
pub fn fig_federation(
    sites_list: &[usize],
    wan_gbps_list: &[f64],
    skew_list: &[f64],
    nodes: usize,
    tasks_per_node: usize,
    threads: usize,
) -> Vec<FederationPoint> {
    use crate::federation::PlacementMode;
    let nodes = nodes.max(2);
    let threads = threads.max(1);
    let mut rows = Vec::new();
    for &n_sites in sites_list {
        for &wan in wan_gbps_list {
            for &skew in skew_list {
                for mode in [
                    PlacementMode::Affinity,
                    PlacementMode::AlwaysHome,
                    PlacementMode::RandomSite,
                ] {
                    let mut cfg = Config::with_nodes(nodes);
                    cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
                    cfg.split_into_sites(n_sites);
                    for s in cfg.federation.sites.iter_mut() {
                        s.wan_bps = crate::util::units::gbps(wan);
                    }
                    cfg.federation.placement = mode;
                    cfg.federation.skew = skew;
                    cfg.sim.threads = threads;
                    let mut catalog = Catalog::new();
                    for e in 0..nodes {
                        catalog.insert(ObjectId(e as u64), 32 * crate::util::units::MB);
                    }
                    let tasks = (nodes * tasks_per_node) as u64;
                    let task_list: Vec<(f64, Task)> = (0..tasks)
                        .map(|i| {
                            (
                                i as f64 * 0.005,
                                Task::with_inputs(TaskId(i), vec![ObjectId(i % nodes as u64)]),
                            )
                        })
                        .collect();
                    let mut spec = SimWorkloadSpec::new(task_list);
                    spec.prewarm = (0..nodes).map(|e| (e, ObjectId(e as u64))).collect();
                    let out = SimDriver::new(cfg, spec, catalog).run();
                    rows.push(FederationPoint {
                        sites: n_sites.max(1),
                        threads,
                        wan_gbps: wan,
                        skew,
                        placement: mode.label(),
                        tasks: out.metrics.tasks_done,
                        makespan_s: out.makespan_s,
                        wan_bytes: out.metrics.wan_bytes,
                        cross_site_tasks: out.metrics.cross_site_tasks,
                        c2c_bytes: out.metrics.c2c_bytes,
                        gpfs_bytes: out.metrics.gpfs_bytes,
                    });
                }
            }
        }
    }
    rows
}

/// Print the federation table and write its CSV under `dir`. Shared by
/// the `fig_federation` bench and `falkon sweep --figure federation`.
/// Returns the CSV path.
pub fn emit_federation(
    rows: &[FederationPoint],
    dir: &std::path::Path,
) -> std::io::Result<std::path::PathBuf> {
    use crate::util::csv::CsvWriter;
    let mut csv = CsvWriter::new(
        dir.join("fig_federation.csv"),
        &[
            "sites",
            "threads",
            "wan_gbps",
            "skew",
            "placement",
            "tasks",
            "makespan_s",
            "wan_bytes",
            "cross_site_tasks",
            "c2c_bytes",
            "gpfs_bytes",
        ],
    );
    println!(
        "{:<6} {:>8} {:>5} {:<10} {:>7} {:>11} {:>12} {:>11} {:>12}",
        "sites", "wan", "skew", "placement", "tasks", "makespan", "wan-bytes", "cross-site", "c2c"
    );
    for r in rows {
        println!(
            "{:<6} {:>6.2}G {:>5.2} {:<10} {:>7} {:>10.1}s {:>12} {:>11} {:>12}",
            r.sites,
            r.wan_gbps,
            r.skew,
            r.placement,
            r.tasks,
            r.makespan_s,
            r.wan_bytes,
            r.cross_site_tasks,
            r.c2c_bytes
        );
        csv.rowf(&[
            &r.sites,
            &r.threads,
            &r.wan_gbps,
            &r.skew,
            &r.placement,
            &r.tasks,
            &r.makespan_s,
            &r.wan_bytes,
            &r.cross_site_tasks,
            &r.c2c_bytes,
            &r.gpfs_bytes,
        ]);
    }
    csv.finish()
}

// ---------------------------------------------------------------- Fig 3/4

/// One point of Figures 3/4: aggregate throughput for a configuration at
/// a node count.
#[derive(Debug, Clone)]
pub struct ThroughputPoint {
    /// Configuration label (paper legend).
    pub config: &'static str,
    /// Node count.
    pub nodes: usize,
    /// Aggregate throughput, bits/sec.
    pub bps: f64,
}

/// Figures 3 (read) / 4 (read+write): throughput of 100 MB files across
/// configurations and node counts.
pub fn fig3_fig4(read_write: bool, nodes_list: &[usize], tasks_per_node: usize) -> Vec<ThroughputPoint> {
    let file_bytes = 100 * crate::util::units::MB;
    let mut rows = Vec::new();
    for &nodes in nodes_list {
        let cfg = Config::with_nodes(nodes);
        // Model envelopes (configurations (1) and (2)).
        rows.push(ThroughputPoint {
            config: MbConfig::ModelLocalDisk.label(),
            nodes,
            bps: if read_write {
                model::local_disk_rw_bps(&cfg, nodes, file_bytes)
            } else {
                model::local_disk_read_bps(&cfg, nodes, file_bytes)
            },
        });
        rows.push(ThroughputPoint {
            config: MbConfig::ModelGpfs.label(),
            nodes,
            bps: if read_write {
                model::gpfs_rw_bps(&cfg, nodes, file_bytes)
            } else {
                model::gpfs_read_bps(&cfg, nodes, file_bytes)
            },
        });
        // Simulated configurations (3)–(8); the paper omits (4) in these
        // two figures (it matches (3) at 100 MB), so we do too.
        for mb in MbConfig::SIMULATED {
            if mb == MbConfig::FirstAvailableWrapper {
                continue;
            }
            let exp = microbench::generate(mb, nodes, file_bytes, read_write, tasks_per_node);
            let out = SimDriver::new(exp.config, exp.spec, exp.catalog).run();
            let bps = if read_write {
                out.metrics.rw_throughput_bps()
            } else {
                out.metrics.read_throughput_bps()
            };
            rows.push(ThroughputPoint {
                config: mb.label(),
                nodes,
                bps,
            });
        }
    }
    rows
}

// ------------------------------------------------------------------ Fig 5

/// One point of Figure 5: throughput and task rate vs file size on 64
/// nodes.
#[derive(Debug, Clone)]
pub struct FileSizePoint {
    /// Configuration label.
    pub config: &'static str,
    /// Read+write (true) or read-only.
    pub read_write: bool,
    /// File size in bytes.
    pub file_bytes: u64,
    /// Aggregate throughput, bits/sec.
    pub bps: f64,
    /// Task completion rate, tasks/sec.
    pub tasks_per_s: f64,
}

/// Figure 5: file-size sweep on 64 nodes for Model (GPFS),
/// first-available, and first-available + wrapper.
pub fn fig5(sizes: &[u64], tasks_per_node: usize) -> Vec<FileSizePoint> {
    let nodes = 64;
    let mut rows = Vec::new();
    for &rw in &[false, true] {
        for &size in sizes {
            let cfg = Config::with_nodes(nodes);
            rows.push(FileSizePoint {
                config: MbConfig::ModelGpfs.label(),
                read_write: rw,
                file_bytes: size,
                bps: if rw {
                    model::gpfs_rw_bps(&cfg, nodes, size)
                } else {
                    model::gpfs_read_bps(&cfg, nodes, size)
                },
                tasks_per_s: f64::NAN,
            });
            for mb in [MbConfig::FirstAvailable, MbConfig::FirstAvailableWrapper] {
                let exp = microbench::generate(mb, nodes, size, rw, tasks_per_node);
                let out = SimDriver::new(exp.config, exp.spec, exp.catalog).run();
                rows.push(FileSizePoint {
                    config: mb.label(),
                    read_write: rw,
                    file_bytes: size,
                    bps: if rw {
                        out.metrics.rw_throughput_bps()
                    } else {
                        out.metrics.read_throughput_bps()
                    },
                    tasks_per_s: out.metrics.task_rate(),
                });
            }
        }
    }
    rows
}

// ------------------------------------------------------------- Fig 8/9/11

/// Stacking-experiment configuration axis (the four §5.3 lines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StackConfig {
    /// Data diffusion over compressed images.
    DiffusionGz,
    /// Data diffusion over uncompressed images.
    DiffusionFit,
    /// GPFS baseline over compressed images.
    GpfsGz,
    /// GPFS baseline over uncompressed images.
    GpfsFit,
}

impl StackConfig {
    /// All four lines.
    pub const ALL: [StackConfig; 4] = [
        StackConfig::DiffusionGz,
        StackConfig::DiffusionFit,
        StackConfig::GpfsGz,
        StackConfig::GpfsFit,
    ];

    /// Figure label.
    pub fn label(&self) -> &'static str {
        match self {
            StackConfig::DiffusionGz => "Data Diffusion (GZ)",
            StackConfig::DiffusionFit => "Data Diffusion (FIT)",
            StackConfig::GpfsGz => "GPFS (GZ)",
            StackConfig::GpfsFit => "GPFS (FIT)",
        }
    }

    /// Whether this line uses data diffusion.
    pub fn caching(&self) -> bool {
        matches!(self, StackConfig::DiffusionGz | StackConfig::DiffusionFit)
    }

    /// Data format on persistent storage.
    pub fn format(&self) -> DataFormat {
        match self {
            StackConfig::DiffusionGz | StackConfig::GpfsGz => DataFormat::Gz,
            StackConfig::DiffusionFit | StackConfig::GpfsFit => DataFormat::Fit,
        }
    }
}

/// Run one stacking experiment cell.
pub fn run_stacking(
    cpus: usize,
    row: WorkloadRow,
    sc: StackConfig,
    scale: f64,
    seed: u64,
) -> RunOutcome {
    let cfg = if sc.caching() {
        presets::stacking(cpus)
    } else {
        presets::stacking_gpfs_baseline(cpus)
    };
    let w = astro::generate(&cfg, row, sc.format(), sc.caching(), scale, seed);
    SimDriver::new(cfg, w.spec, w.catalog).run()
}

/// One point of Figures 8/9/11: normalized time per stack per CPU.
#[derive(Debug, Clone)]
pub struct StackPoint {
    /// Configuration label.
    pub config: &'static str,
    /// CPU count.
    pub cpus: usize,
    /// Workload locality.
    pub locality: f64,
    /// Time per stacking operation per CPU, seconds.
    pub time_per_stack_s: f64,
    /// Local cache-hit ratio achieved.
    pub hit_ratio: f64,
    /// The full outcome, for deeper analysis.
    pub outcome: RunOutcome,
}

/// Figures 8/9: time per stack as CPUs scale, at one locality.
pub fn fig8_fig9(locality: f64, cpus_list: &[usize], scale: f64) -> Vec<StackPoint> {
    let row = astro::row_for_locality(locality);
    let mut rows = Vec::new();
    for &cpus in cpus_list {
        for sc in StackConfig::ALL {
            let out = run_stacking(cpus, row, sc, scale, 20080610);
            rows.push(StackPoint {
                config: sc.label(),
                cpus,
                locality: row.locality,
                time_per_stack_s: out.time_per_task_per_cpu(cpus),
                hit_ratio: out.metrics.local_hit_ratio(),
                outcome: out,
            });
        }
    }
    rows
}

/// Figure 11 (and the data behind 10/12/13): locality sweep at 128 CPUs.
pub fn fig11_sweep(cpus: usize, scale: f64) -> Vec<StackPoint> {
    let mut rows = Vec::new();
    for row in astro::TABLE2 {
        for sc in StackConfig::ALL {
            let out = run_stacking(cpus, row, sc, scale, 20080610);
            rows.push(StackPoint {
                config: sc.label(),
                cpus,
                locality: row.locality,
                time_per_stack_s: out.time_per_task_per_cpu(cpus),
                hit_ratio: out.metrics.local_hit_ratio(),
                outcome: out,
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_small_sweep_has_expected_shape() {
        // Tiny sweep (2 nodes) sanity: max-compute-util@100% beats the
        // GPFS model at equal node count on large files.
        let rows = fig3_fig4(false, &[2], 4);
        let get = |label: &str| {
            rows.iter()
                .find(|r| r.config == label)
                .map(|r| r.bps)
                .unwrap()
        };
        let warm = get(MbConfig::MaxComputeUtil100.label());
        let cold = get(MbConfig::MaxComputeUtil0.label());
        assert!(warm > 0.0 && cold > 0.0);
    }

    #[test]
    fn fig_shard_scaling_rows_are_complete() {
        // Small sweep sanity: every shard count retires the whole
        // workload, the baseline row has speedup 1.0, and multi-shard
        // rows account their dispatch batches. Throughput ratios are
        // asserted in `tests/shard_scaling.rs`, not here — this test
        // must stay load-tolerant.
        let rows = fig_shard_scaling(&[1, 2, 4], 512, 8);
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert_eq!(r.tasks, 512, "shards={} must retire all tasks", r.shards);
            assert!(r.tasks_per_s > 0.0);
            assert!(r.batches > 0, "shards={} must account batches", r.shards);
        }
        assert!((rows[0].speedup - 1.0).abs() < 1e-12, "baseline speedup is 1");
        assert_eq!(rows[0].steals, 0, "one shard cannot steal");
    }

    #[test]
    fn fig_scale_rows_are_complete() {
        // Tiny grid sanity: every cell retires the whole workload and
        // reports positive throughput. Wall-clock ratios are a bench
        // concern, not a test one — this must stay load-tolerant.
        let rows = fig_scale(&[4, 16], &[256], 1, &[1]);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.tasks, 256, "executors={} must retire all tasks", r.executors);
            assert!(r.events >= r.tasks, "each task takes >= 1 event");
            assert!(r.makespan_s > 0.0);
            assert!(r.events_per_s > 0.0);
            assert_eq!(r.speedup, 1.0, "single-thread-axis rows are their own baseline");
        }
        // Linux CI reports a real high-water mark; elsewhere 0.0 is fine.
        if cfg!(target_os = "linux") {
            assert!(rows[0].peak_rss_mb > 0.0);
        }
    }

    #[test]
    fn vm_hwm_parse_degrades_to_zero() {
        assert_eq!(parse_vm_hwm("VmPeak:\t  100 kB\nVmHWM:\t  2048 kB\n"), 2.0);
        // Kernels without per-process HWM accounting omit the field:
        // the figure still emits, with a zero RSS column.
        assert_eq!(parse_vm_hwm("VmPeak:\t  100 kB\nVmRSS:\t  50 kB\n"), 0.0);
        assert_eq!(parse_vm_hwm(""), 0.0);
        assert_eq!(parse_vm_hwm("VmHWM:\tgarbage kB\n"), 0.0);
    }

    #[test]
    fn fig_federation_affinity_beats_both_baselines() {
        // The PR's acceptance criterion: at >= 2 sites, Pilot-Data
        // affinity placement must beat always-home AND random-site on
        // makespan AND WAN bytes.
        let rows = fig_federation(&[2], &[0.25], &[0.5], 8, 4, 2);
        assert_eq!(rows.len(), 3);
        let get = |p: &str| rows.iter().find(|r| r.placement == p).unwrap();
        let (aff, home, random) = (get("affinity"), get("home"), get("random"));
        for r in &rows {
            assert_eq!(r.tasks, 32, "{}: run must drain", r.placement);
            assert!(r.makespan_s > 0.0);
        }
        assert!(aff.cross_site_tasks > 0, "affinity must ship tasks between sites");
        assert!(
            home.wan_bytes > 0 && random.wan_bytes > 0,
            "baselines must ship data over the WAN: home={} random={}",
            home.wan_bytes,
            random.wan_bytes
        );
        assert!(
            aff.wan_bytes < home.wan_bytes && aff.wan_bytes < random.wan_bytes,
            "affinity must win on WAN bytes: aff={} home={} random={}",
            aff.wan_bytes,
            home.wan_bytes,
            random.wan_bytes
        );
        assert!(
            aff.makespan_s < home.makespan_s && aff.makespan_s < random.makespan_s,
            "affinity must win on makespan: aff={} home={} random={}",
            aff.makespan_s,
            home.makespan_s,
            random.makespan_s
        );
    }

    #[test]
    fn fig2_measured_chord_costs_more_than_central() {
        let rows = fig2_measured(&[8], 4);
        assert_eq!(rows.len(), 2);
        let central = rows.iter().find(|r| r.backend == "central").unwrap();
        let chord = rows.iter().find(|r| r.backend == "chord").unwrap();
        assert_eq!(central.tasks, chord.tasks, "same workload both backends");
        assert_eq!(central.index_lookups, chord.index_lookups);
        assert_eq!(central.index_hops, 0);
        assert!(chord.index_hops > 0);
        assert!(chord.index_cost_s > central.index_cost_s);
    }

    #[test]
    fn fig_drp_compares_all_three_policies_on_real_runs() {
        let rows = fig_drp(8, 160);
        assert_eq!(rows.len(), 3);
        let labels: Vec<&str> = rows.iter().map(|r| r.policy).collect();
        assert_eq!(labels, vec!["one-at-a-time", "adaptive", "all-at-once"]);
        for r in &rows {
            assert_eq!(r.tasks, 160, "{}: run must drain", r.policy);
            assert!(r.peak_executors <= r.max_executors, "{}: pool cap", r.policy);
            assert!(r.executors_joined > 0, "{}: pool must grow", r.policy);
            assert!(
                r.executors_released > 0,
                "{}: pool must shrink in the lull",
                r.policy
            );
            assert!(r.alloc_wait_s > 0.0, "{}: allocation latency costs", r.policy);
            assert!(!r.outcome.metrics.pool_timeline.is_empty());
            for s in &r.outcome.metrics.pool_timeline {
                assert!(s.allocated + s.pending <= r.max_executors);
            }
        }
        // one-at-a-time grows one grant per evaluation; all-at-once takes
        // the whole headroom in one request. More requests, same ceiling.
        let one = rows.iter().find(|r| r.policy == "one-at-a-time").unwrap();
        let all = rows.iter().find(|r| r.policy == "all-at-once").unwrap();
        assert!(
            one.alloc_requests >= all.alloc_requests,
            "one-at-a-time ({}) should need at least as many requests as all-at-once ({})",
            one.alloc_requests,
            all.alloc_requests
        );
    }

    #[test]
    fn fig_diffusion_replication_wins_and_scales() {
        let rows = fig_diffusion(&[4, 8], 24);
        assert_eq!(rows.len(), 4);
        let get = |nodes: usize, mode: &str| {
            rows.iter()
                .find(|r| r.nodes == nodes && r.mode == mode)
                .unwrap()
        };
        for &n in &[4usize, 8] {
            let on = get(n, "replication-on");
            let off = get(n, "replication-off");
            assert_eq!(on.tasks, (n * 24) as u64, "n={n}: run must drain");
            assert_eq!(on.tasks, off.tasks);
            assert_eq!(off.replicas_created, 0);
            assert!(on.replicas_created > 0, "n={n}: hot set must replicate");
            assert!(on.replica_hits > 0, "n={n}: staged copies must serve hits");
            assert!(
                on.local_hit_ratio > off.local_hit_ratio,
                "n={n}: replication must lift the local hit ratio: {} vs {}",
                on.local_hit_ratio,
                off.local_hit_ratio
            );
            assert!(
                on.read_bps > off.read_bps,
                "n={n}: replication must lift aggregate read bandwidth: {} vs {}",
                on.read_bps,
                off.read_bps
            );
        }
        // The paper's headline: aggregate read throughput scales with the
        // cache-node count when data diffuses.
        let on4 = get(4, "replication-on");
        let on8 = get(8, "replication-on");
        assert!(
            on8.read_bps > 1.4 * on4.read_bps,
            "throughput must scale with cache nodes: {} @4 vs {} @8",
            on4.read_bps,
            on8.read_bps
        );
    }

    #[test]
    fn fig_qos_three_way_share_policy_sweep() {
        let rows = fig_qos(&[6], 20);
        assert_eq!(rows.len(), 3);
        let off = rows.iter().find(|r| r.mode == "off").unwrap();
        let binary = rows.iter().find(|r| r.mode == "binary").unwrap();
        let weighted = rows.iter().find(|r| r.mode == "weighted").unwrap();
        assert_eq!(off.tasks, 120, "run must drain");
        assert_eq!(off.tasks, binary.tasks);
        assert_eq!(off.tasks, weighted.tasks);
        // Deferral profile: off never defers; binary must under the
        // saturating load; weighted (hard cap 1.0) throttles instead.
        assert_eq!(off.staging_deferred, 0);
        assert!(
            binary.staging_deferred > 0,
            "saturating staging load must trigger binary deferrals"
        );
        assert_eq!(weighted.staging_deferred, 0, "weighted admits-but-throttles");
        // Replication converges in every mode.
        for r in [off, binary, weighted] {
            assert!(r.replicas_created > 0, "{}: staging must converge", r.mode);
            assert!(r.p99_task_s > 0.0 && r.p99_task_s.is_finite());
            assert!(r.p50_task_s <= r.p90_task_s && r.p90_task_s <= r.p99_task_s);
        }
        // Headline 1: metering (either kind) can only help the
        // foreground tail under saturating staging load.
        assert!(
            binary.p99_task_s <= off.p99_task_s + 1e-9,
            "binary p99 {} must not exceed off p99 {}",
            binary.p99_task_s,
            off.p99_task_s
        );
        assert!(
            weighted.p99_task_s <= off.p99_task_s + 1e-9,
            "weighted p99 {} must not exceed off p99 {}",
            weighted.p99_task_s,
            off.p99_task_s
        );
        // Headline 2: weighted keeps staging moving — bytes staged never
        // fall below binary's stop-start deferral schedule.
        assert!(
            weighted.replica_bytes_staged >= binary.replica_bytes_staged,
            "weighted staged {} must be >= binary staged {}",
            weighted.replica_bytes_staged,
            binary.replica_bytes_staged
        );
        // Per-class accounting flows through: staging bytes in the
        // class breakdown match the staged bytes.
        for r in [off, binary, weighted] {
            assert_eq!(
                r.class_bytes[1] + r.class_bytes[2],
                r.replica_bytes_staged,
                "{}: class accounting must match staged bytes",
                r.mode
            );
        }
    }

    #[test]
    fn stacking_cell_runs() {
        let row = astro::row_for_locality(30.0);
        let out = run_stacking(4, row, StackConfig::DiffusionGz, 0.002, 1);
        assert!(out.metrics.tasks_done > 0);
        assert!(out.makespan_s > 0.0);
    }

    #[test]
    fn diffusion_beats_gpfs_at_high_locality_and_scale() {
        // The paper's headline: once GPFS saturates (beyond ~16 CPUs for
        // FIT, later for GZ), data diffusion wins, and the gap grows with
        // CPU count. At small CPU counts GPFS can be competitive (Fig 9's
        // left edge) — the claim is about scale.
        let row = astro::row_for_locality(30.0);
        let dd = run_stacking(64, row, StackConfig::DiffusionGz, 0.02, 1);
        let base = run_stacking(64, row, StackConfig::GpfsGz, 0.02, 1);
        assert!(
            dd.makespan_s < base.makespan_s,
            "diffusion {} vs gpfs {}",
            dd.makespan_s,
            base.makespan_s
        );
        assert!(dd.metrics.local_hit_ratio() > 0.5);
        // And GPFS-FIT saturates before GPFS-GZ (3x the bytes).
        let fit = run_stacking(64, row, StackConfig::GpfsFit, 0.02, 1);
        assert!(fit.makespan_s > base.makespan_s);
    }
}
