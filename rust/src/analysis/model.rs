//! Analytic throughput envelopes — the paper's "Model" curves.
//!
//! Configuration (1) "Model (local disk)" and (2) "Model (persistent
//! storage)" in §4.3 are not Falkon runs but the theoretical envelopes of
//! the two storage substrates. We derive them from the same calibration
//! constants the simulator uses, so measured-vs-model gaps in our figures
//! mean the same thing they do in the paper.

use crate::config::Config;

/// Aggregate local-disk read throughput for `nodes` nodes reading files
/// of `file_bytes` (bits/sec). Linear in nodes; per-file open overhead
/// bites at small sizes.
pub fn local_disk_read_bps(cfg: &Config, nodes: usize, file_bytes: u64) -> f64 {
    let per_file_s = cfg.local_disk.open_s + (file_bytes as f64 * 8.0) / cfg.local_disk.read_bps;
    nodes as f64 * (file_bytes as f64 * 8.0) / per_file_s
}

/// Aggregate local-disk read+write throughput (bits/sec moved, counting
/// both directions, as the paper does).
pub fn local_disk_rw_bps(cfg: &Config, nodes: usize, file_bytes: u64) -> f64 {
    let bits = file_bytes as f64 * 8.0;
    let per_file_s =
        cfg.local_disk.open_s + bits / cfg.local_disk.read_bps + bits / cfg.local_disk.write_bps;
    nodes as f64 * (2.0 * bits) / per_file_s
}

/// Aggregate GPFS read throughput for `nodes` concurrent clients
/// (bits/sec): client NICs bind below the server cap, the 3.4 Gb/s
/// aggregate cap above it; per-file metadata costs bite at small sizes.
pub fn gpfs_read_bps(cfg: &Config, nodes: usize, file_bytes: u64) -> f64 {
    let bits = file_bytes as f64 * 8.0;
    let agg_cap = (nodes as f64 * cfg.shared_fs.per_client_cap_bps).min(cfg.shared_fs.read_cap_bps);
    // Metadata server is shared: at `nodes` concurrent openers the open
    // cost serializes, so the per-file effective time includes the queue.
    let meta_s = cfg.shared_fs.meta_op_s * cfg.shared_fs.meta_ops_open as f64 * nodes as f64;
    let xfer_s = bits / (agg_cap / nodes as f64);
    nodes as f64 * bits / (meta_s + xfer_s)
}

/// Aggregate GPFS read+write throughput (bits/sec, both directions).
pub fn gpfs_rw_bps(cfg: &Config, nodes: usize, file_bytes: u64) -> f64 {
    let bits = file_bytes as f64 * 8.0;
    let read_cap = (nodes as f64 * cfg.shared_fs.per_client_cap_bps).min(cfg.shared_fs.read_cap_bps);
    let write_cap =
        (nodes as f64 * cfg.shared_fs.per_client_cap_bps).min(cfg.shared_fs.write_cap_bps);
    let meta_s = cfg.shared_fs.meta_op_s * (2 * cfg.shared_fs.meta_ops_open) as f64 * nodes as f64;
    let per_file_s = meta_s + bits / (read_cap / nodes as f64) + bits / (write_cap / nodes as f64);
    nodes as f64 * (2.0 * bits) / per_file_s
}

/// Ideal single-node time per stacking task, seconds — the "ideal"
/// reference point in Fig 11 (all data local, no contention).
pub fn ideal_stack_time_s(cfg: &Config, gz: bool) -> f64 {
    let bytes = cfg.app.fit_bytes; // data is cached uncompressed
    let read_s = cfg.local_disk.open_s + (bytes as f64 * 8.0) / cfg.local_disk.read_bps;
    let cpu_s = cfg.app.radec2xy_s + cfg.app.stack_compute_s;
    // Amortized decompression: charged once per file per `locality` uses;
    // the single-node ideal in the paper assumes a warm local working set,
    // so GZ only differs via the (amortized, small) decompression.
    let decompress = if gz { 0.0 } else { 0.0 };
    read_s + cpu_s + decompress
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{gbps, GB, MB};

    #[test]
    fn local_disk_scales_linearly() {
        let cfg = Config::with_nodes(64);
        let t1 = local_disk_read_bps(&cfg, 1, 100 * MB);
        let t64 = local_disk_read_bps(&cfg, 64, 100 * MB);
        assert!((t64 / t1 - 64.0).abs() < 1e-6);
    }

    #[test]
    fn gpfs_saturates_at_cap() {
        let cfg = Config::with_nodes(64);
        // Large files, many nodes: pinned at ~3.4 Gb/s.
        let t = gpfs_read_bps(&cfg, 64, GB);
        assert!(t < gbps(3.4) && t > gbps(3.0), "t={t}");
        // One node: NIC-bound, ~1 Gb/s.
        let t1 = gpfs_read_bps(&cfg, 1, GB);
        assert!(t1 < gbps(1.0) && t1 > gbps(0.9), "t1={t1}");
    }

    #[test]
    fn gpfs_small_files_metadata_bound() {
        let cfg = Config::with_nodes(64);
        let small = gpfs_read_bps(&cfg, 64, 1_000);
        let large = gpfs_read_bps(&cfg, 64, 100 * MB);
        assert!(
            small < large / 1000.0,
            "small files must be orders slower: {small} vs {large}"
        );
    }

    #[test]
    fn rw_below_read() {
        let cfg = Config::with_nodes(64);
        assert!(gpfs_rw_bps(&cfg, 64, 100 * MB) < gpfs_read_bps(&cfg, 64, 100 * MB));
        assert!(local_disk_rw_bps(&cfg, 64, 100 * MB) < local_disk_read_bps(&cfg, 64, 100 * MB));
    }

    #[test]
    fn paper_shape_rw_caps_near_1_1_gbps() {
        let cfg = Config::with_nodes(64);
        let t = gpfs_rw_bps(&cfg, 64, GB);
        assert!(t > gbps(0.9) && t < gbps(1.3), "t={t}");
    }
}
