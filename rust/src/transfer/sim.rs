//! Simulated transfer plane: the share policy over the fair-share flow
//! network.
//!
//! Wraps the wired [`SimTestbed`] so that every simulated byte movement
//! — foreground task I/O and background staging alike — starts through
//! one class-tagged entry point: background staging is admitted against
//! the *measured* utilization of the source executor's egress resources
//! (NIC-out and disk-read), i.e. the same max-min-fair rates the flows
//! themselves experience, and each flow starts carrying its class's
//! fair-share weight (unit under the binary policy, the configured
//! [`super::ClassWeights`] under the weighted policy), so in-flight
//! throttling happens inside the same contention physics. The sim
//! driver owns one [`SimTransferPlane`] instead of a bare testbed.

use super::{
    build_share_policy, Admission, AdmissionController, TransferClass, TransferPlane,
    TransferRequest, TransferStats,
};
use crate::config::TransferConfig;
use crate::index::central::ExecutorId;
use crate::sim::flownet::{FlowId, FlowSpec};
use crate::storage::testbed::{SimTestbed, TransferKind};

/// The simulation driver's transfer plane.
pub struct SimTransferPlane {
    /// The wired testbed (flow network + resource handles + metadata
    /// server). Public: the driver still couples flow completions to the
    /// DES through `testbed.net` and queues metadata ops directly.
    pub testbed: SimTestbed,
    ctl: AdmissionController,
    /// Flows started per class: [foreground, staging, prestage].
    started: [u64; 3],
}

impl SimTransferPlane {
    /// Plane over a wired testbed with the configured share policy.
    pub fn new(testbed: SimTestbed, cfg: &TransferConfig) -> Self {
        SimTransferPlane {
            testbed,
            ctl: AdmissionController::with_policy(build_share_policy(cfg)),
            started: [0; 3],
        }
    }

    /// Start a class-tagged flow now (admission already granted — the
    /// driver calls this for foreground flows directly and for
    /// background flows after [`TransferPlane::submit`]/
    /// [`TransferPlane::readmit`] returned them). The flow carries the
    /// class's fair-share weight under the configured policy.
    pub fn start(
        &mut self,
        now: f64,
        class: TransferClass,
        kind: TransferKind,
        bytes: u64,
    ) -> FlowId {
        self.started[class.index()] += 1;
        let rs = self.testbed.resource_set(kind);
        let spec = FlowSpec::new(bytes).weight(self.ctl.weight_of(class)).over(&rs);
        self.testbed.net.start(now, spec)
    }

    /// Start a class-tagged flow over an explicit resource set. The
    /// federated parallel driver splits cross-site transfers into
    /// per-site leg halves (see the `SimTestbed` egress/ingress
    /// builders) that don't correspond to any single [`TransferKind`].
    pub fn start_over(
        &mut self,
        now: f64,
        class: TransferClass,
        rs: &crate::storage::testbed::ResourceSet,
        bytes: u64,
    ) -> FlowId {
        self.started[class.index()] += 1;
        let spec = FlowSpec::new(bytes).weight(self.ctl.weight_of(class)).over(rs);
        self.testbed.net.start(now, spec)
    }

    /// Flows started per class: (foreground, staging, prestage).
    pub fn class_counts(&self) -> (u64, u64, u64) {
        (self.started[0], self.started[1], self.started[2])
    }

    /// Egress utilization of one executor: the larger of its NIC-out and
    /// disk-read utilization (a peer transfer crosses both; whichever is
    /// more loaded is what a new transfer would contend on).
    pub fn source_utilization(&mut self, e: ExecutorId) -> f64 {
        Self::util_of(&mut self.testbed, e)
    }

    fn util_of(testbed: &mut SimTestbed, e: ExecutorId) -> f64 {
        match testbed.nodes.get(e).copied() {
            None => 0.0,
            Some(n) => {
                let nic = testbed.net.utilization(n.nic_out);
                let disk = testbed.net.utilization(n.disk_read);
                nic.max(disk)
            }
        }
    }
}

impl TransferPlane for SimTransferPlane {
    fn submit(&mut self, req: TransferRequest) -> Admission {
        if !req.class.is_background() {
            return Admission::Start;
        }
        let util = Self::util_of(&mut self.testbed, req.src);
        self.ctl.offer(req, util)
    }

    fn readmit(&mut self) -> Vec<TransferRequest> {
        let testbed = &mut self.testbed;
        self.ctl.readmit(|e| Self::util_of(testbed, e))
    }

    fn executor_released(&mut self, exec: ExecutorId) -> Vec<TransferRequest> {
        self.ctl.executor_released(exec)
    }

    fn deferred_len(&self) -> usize {
        self.ctl.deferred_len()
    }

    fn stats(&self) -> TransferStats {
        self.ctl.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::storage::object::ObjectId;
    use crate::util::units::MB;

    fn plane(nodes: usize, budget: f64) -> SimTransferPlane {
        let cfg = Config::with_nodes(nodes);
        let tcfg = TransferConfig {
            staging_budget: budget,
            ..TransferConfig::default()
        };
        SimTransferPlane::new(SimTestbed::new(&cfg), &tcfg)
    }

    fn staging(obj: u64, src: usize, dst: usize) -> TransferRequest {
        TransferRequest {
            class: TransferClass::Staging,
            obj: ObjectId(obj),
            src,
            dst,
            bytes: MB,
        }
    }

    #[test]
    fn idle_source_admits_loaded_source_defers() {
        let mut p = plane(3, 0.2);
        assert_eq!(p.submit(staging(1, 0, 1)), Admission::Start);
        // A foreground peer fetch from node 0 loads its disk-read well
        // past the 0.2 budget (dst disk-write binds at 230 of 470 Mb/s
        // source read ⇒ ~0.49 utilization).
        let fid = p.start(
            0.0,
            TransferClass::Foreground,
            TransferKind::Peer { src: 0, dst: 2 },
            100 * MB,
        );
        assert!(p.source_utilization(0) > 0.2);
        assert_eq!(p.submit(staging(2, 0, 1)), Admission::Defer);
        assert_eq!(p.deferred_len(), 1);
        assert!(p.readmit().is_empty(), "still loaded");
        // The foreground flow completes: the source drains and the
        // deferred staging is re-admitted.
        p.testbed.net.remove_flow(0.0, fid);
        let back = p.readmit();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].obj, ObjectId(2));
    }

    #[test]
    fn foreground_never_defers_even_when_saturated() {
        let mut p = plane(2, 0.0);
        let _f = p.start(
            0.0,
            TransferClass::Foreground,
            TransferKind::Peer { src: 0, dst: 1 },
            100 * MB,
        );
        let req = TransferRequest {
            class: TransferClass::Foreground,
            obj: ObjectId(9),
            src: 0,
            dst: 1,
            bytes: MB,
        };
        assert_eq!(p.submit(req), Admission::Start);
        assert_eq!(p.stats().deferred, 0);
    }

    #[test]
    fn class_counts_track_started_flows() {
        let mut p = plane(2, 1.0);
        p.start(0.0, TransferClass::Foreground, TransferKind::LocalRead { node: 0 }, MB);
        p.start(0.0, TransferClass::Staging, TransferKind::Peer { src: 0, dst: 1 }, MB);
        p.start(0.0, TransferClass::Prestage, TransferKind::Peer { src: 0, dst: 1 }, MB);
        p.start(0.0, TransferClass::Foreground, TransferKind::GpfsRead { node: 1 }, MB);
        assert_eq!(p.class_counts(), (2, 1, 1));
    }

    #[test]
    fn unknown_source_reads_as_idle() {
        let mut p = plane(2, 0.2);
        assert_eq!(p.source_utilization(99), 0.0);
        assert_eq!(p.submit(staging(1, 99, 0)), Admission::Start);
    }

    #[test]
    fn weighted_plane_starts_background_flows_below_unit_weight() {
        use crate::transfer::{ClassWeights, SharePolicyKind};
        let cfg = Config::with_nodes(2);
        let tcfg = TransferConfig {
            share_policy: SharePolicyKind::Weighted,
            staging_budget: 1.0,
            class_weights: ClassWeights::default(),
        };
        let mut p = SimTransferPlane::new(SimTestbed::new(&cfg), &tcfg);
        let fg = p.start(
            0.0,
            TransferClass::Foreground,
            TransferKind::LocalRead { node: 0 },
            100 * MB,
        );
        let st = p.start(
            0.0,
            TransferClass::Staging,
            TransferKind::LocalRead { node: 0 },
            100 * MB,
        );
        assert_eq!(p.testbed.net.flow_weight(fg), 1.0);
        assert_eq!(p.testbed.net.flow_weight(st), 0.25);
        // Contending on node 0's disk-read: 80/20 split, not 50/50.
        let cap = p.testbed.net.capacity(p.testbed.nodes[0].disk_read);
        assert!((p.testbed.net.rate(fg) - 0.8 * cap).abs() < 1.0);
        assert!((p.testbed.net.rate(st) - 0.2 * cap).abs() < 1.0);
        // Weighted with budget 1.0 never defers: admit-but-throttle.
        assert_eq!(p.submit(staging(7, 0, 1)), Admission::Start);
        assert_eq!(p.stats().deferred, 0);
    }
}
