//! Live transfer plane: admission control over the cache-directory copy
//! path.
//!
//! The live driver moves bytes with real file copies between per-executor
//! cache directories ([`copy_into_cache`] — the one funnel every
//! cache-bound copy goes through, whether it serves a foreground peer
//! fetch, a persistent-storage read, or a staging transfer). The
//! coordinator cannot observe NIC counters for its executor threads, so
//! the live plane meters the closest observable proxy: the source
//! executor's **busy-slot fraction** (a busy slot is a running task, and
//! a running task is doing foreground I/O on that node's disk and NIC).
//! The coordinator refreshes the snapshot every loop iteration via
//! [`LiveTransferPlane::set_load`] and drains re-admitted transfers with
//! [`TransferPlane::readmit`] before dispatching.

use std::path::Path;

use super::{Admission, AdmissionController, TransferPlane, TransferRequest, TransferStats};
use crate::index::central::ExecutorId;
use crate::util::fxhash::FxHashMap;

/// The live driver's transfer plane: admission control fed by a
/// coordinator-maintained per-executor load snapshot.
pub struct LiveTransferPlane {
    ctl: AdmissionController,
    /// Busy-slot fraction per executor (coordinator snapshot).
    load: FxHashMap<ExecutorId, f64>,
}

impl LiveTransferPlane {
    /// Plane with the given staging budget.
    pub fn new(staging_budget: f64) -> Self {
        LiveTransferPlane {
            ctl: AdmissionController::new(staging_budget),
            load: FxHashMap::default(),
        }
    }

    /// Refresh one executor's load (busy slots / capacity, in [0, 1]).
    /// Released executors are forgotten by
    /// [`TransferPlane::executor_released`].
    pub fn set_load(&mut self, exec: ExecutorId, util: f64) {
        self.load.insert(exec, util.clamp(0.0, 1.0));
    }

    fn util(&self, exec: ExecutorId) -> f64 {
        self.load.get(&exec).copied().unwrap_or(0.0)
    }
}

impl TransferPlane for LiveTransferPlane {
    fn submit(&mut self, req: TransferRequest) -> Admission {
        if !req.class.is_background() {
            return Admission::Start;
        }
        let util = self.util(req.src);
        self.ctl.offer(req, util)
    }

    fn readmit(&mut self) -> Vec<TransferRequest> {
        let load = &self.load;
        self.ctl
            .readmit(|e| load.get(&e).copied().unwrap_or(0.0))
    }

    fn executor_released(&mut self, exec: ExecutorId) -> Vec<TransferRequest> {
        self.load.remove(&exec);
        self.ctl.executor_released(exec)
    }

    fn deferred_len(&self) -> usize {
        self.ctl.deferred_len()
    }

    fn stats(&self) -> TransferStats {
        self.ctl.stats()
    }
}

/// The live data path: copy a source file into an executor's cache
/// directory, returning the bytes moved. Every cache-bound copy in the
/// live driver (peer fetch, persistent-storage fetch, staging) funnels
/// through here so all byte movement shares one accounted path.
pub fn copy_into_cache(src: &Path, dst: &Path) -> std::io::Result<u64> {
    std::fs::copy(src, dst)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::object::ObjectId;
    use crate::transfer::TransferClass;

    fn staging(obj: u64, src: usize) -> TransferRequest {
        TransferRequest {
            class: TransferClass::Staging,
            obj: ObjectId(obj),
            src,
            dst: 7,
            bytes: 100,
        }
    }

    #[test]
    fn load_snapshot_gates_admission() {
        let mut p = LiveTransferPlane::new(0.5);
        p.set_load(0, 1.0);
        p.set_load(1, 0.0);
        assert_eq!(p.submit(staging(1, 0)), Admission::Defer);
        assert_eq!(p.submit(staging(2, 1)), Admission::Start);
        // Source 0 drains; the deferred transfer comes back.
        p.set_load(0, 0.0);
        let back = p.readmit();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].obj, ObjectId(1));
        assert_eq!(p.deferred_len(), 0);
    }

    #[test]
    fn unknown_executor_is_idle_and_release_cancels() {
        let mut p = LiveTransferPlane::new(0.5);
        assert_eq!(p.submit(staging(1, 42)), Admission::Start);
        p.set_load(3, 1.0);
        assert_eq!(p.submit(staging(2, 3)), Admission::Defer);
        let cancelled = p.executor_released(3);
        assert_eq!(cancelled.len(), 1);
        assert_eq!(p.stats().cancelled, 1);
        assert_eq!(p.deferred_len(), 0);
    }

    #[test]
    fn copy_into_cache_moves_real_bytes() {
        let dir = std::env::temp_dir().join(format!("dd_xfer_copy_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let src = dir.join("src.bin");
        let dst = dir.join("dst.bin");
        std::fs::write(&src, vec![7u8; 4096]).unwrap();
        let n = copy_into_cache(&src, &dst).unwrap();
        assert_eq!(n, 4096);
        assert_eq!(std::fs::read(&dst).unwrap().len(), 4096);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
