//! Live transfer plane: the share policy over the cache-directory copy
//! path.
//!
//! The live driver moves bytes with real file copies between per-executor
//! cache directories ([`copy_into_cache`] — the one funnel every
//! cache-bound copy goes through, whether it serves a foreground peer
//! fetch, a persistent-storage read, or a staging transfer). Two pieces
//! make the live plane commensurate with the simulator's measured
//! utilization:
//!
//! * **Byte-level egress accounting** ([`EgressLedger`]): every copy out
//!   of an executor's cache directory — foreground peer fetches and
//!   background staging alike — registers its byte count against that
//!   *source* executor while the copy is in flight (the copying thread
//!   is the destination's, but the bytes leave the source's disk/NIC).
//!   Utilization is the in-flight backlog expressed as seconds of the
//!   source's egress bandwidth, clamped to [0, 1] — the same quantity
//!   the sim reads as the rate-sum over the source's NIC-out/disk-read.
//!   This replaces PR 4's busy-slot proxy, which could not see bytes at
//!   all.
//! * **Token-bucket pacing** ([`StagingPacer`]): under the weighted
//!   policy, background copies drain a per-source bucket refilled at the
//!   source's egress rate, with each class charged inversely to its
//!   fair share against one foreground flow
//!   ([`super::ClassWeights::share_vs_foreground`]) — a staging copy at
//!   weight 0.25 proceeds at ~20% of the source's egress, the live
//!   analog of the sim's weighted max-min rate. The binary policy
//!   disables pacing (unit weights: admitted copies run at full speed,
//!   exactly PR 4's behavior).

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::{
    build_share_policy, Admission, AdmissionController, SharePolicyKind, TransferClass,
    TransferPlane, TransferRequest, TransferStats,
};
use crate::config::TransferConfig;
use crate::index::central::ExecutorId;

/// Per-source-executor in-flight egress byte accounting, shared between
/// the coordinator (which reads utilization for admission) and the
/// executor threads (which register their copies). Lock-free: counters
/// are atomics, capacity is fixed at construction.
#[derive(Debug)]
pub struct EgressLedger {
    /// Bytes currently being copied out of each executor's cache.
    inflight: Vec<AtomicU64>,
    /// Egress bandwidth per executor, bits/sec (the tighter of NIC and
    /// local-disk read — the same legs the sim's utilization meters).
    egress_bps: f64,
}

impl EgressLedger {
    /// Ledger for `n` executors with the given per-executor egress
    /// bandwidth (bits/sec).
    pub fn new(n: usize, egress_bps: f64) -> EgressLedger {
        EgressLedger {
            inflight: (0..n).map(|_| AtomicU64::new(0)).collect(),
            egress_bps: egress_bps.max(1.0),
        }
    }

    /// A copy of `bytes` out of `src`'s cache started.
    pub fn begin(&self, src: ExecutorId, bytes: u64) {
        if let Some(c) = self.inflight.get(src) {
            c.fetch_add(bytes, Ordering::Relaxed);
        }
    }

    /// A copy of `bytes` out of `src`'s cache finished (or failed).
    pub fn end(&self, src: ExecutorId, bytes: u64) {
        if let Some(c) = self.inflight.get(src) {
            // Saturating: a release/re-join race must never underflow.
            let _ = c.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(bytes))
            });
        }
    }

    /// Bytes currently in flight out of `src`'s cache.
    pub fn inflight_bytes(&self, src: ExecutorId) -> u64 {
        self.inflight
            .get(src)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Egress utilization in [0, 1]: the in-flight backlog as seconds of
    /// the source's egress bandwidth, clamped — one full second of queued
    /// bytes reads as saturated.
    pub fn utilization(&self, src: ExecutorId) -> f64 {
        (self.inflight_bytes(src) as f64 * 8.0 / self.egress_bps).clamp(0.0, 1.0)
    }
}

/// RAII egress registration: `bytes` are charged against `src` for the
/// guard's lifetime and released on drop (panic-safe accounting inside
/// executor threads).
pub struct EgressGuard {
    ledger: Arc<EgressLedger>,
    src: ExecutorId,
    bytes: u64,
}

impl EgressGuard {
    /// Register `bytes` against `src` on the ledger until dropped.
    pub fn new(ledger: Arc<EgressLedger>, src: ExecutorId, bytes: u64) -> EgressGuard {
        ledger.begin(src, bytes);
        EgressGuard { ledger, src, bytes }
    }
}

impl Drop for EgressGuard {
    fn drop(&mut self) {
        self.ledger.end(self.src, self.bytes);
    }
}

/// Token-bucket state with an explicit clock (testable without
/// sleeping): `take` returns how long the caller must wait before the
/// requested tokens are covered.
#[derive(Debug)]
struct TokenBucket {
    /// Refill rate, tokens (bytes) per second.
    rate: f64,
    /// Burst allowance, tokens.
    burst: f64,
    /// Tokens available at `last` (may go negative: debt = wait time).
    tokens: f64,
    /// Clock of the last refill, seconds.
    last: f64,
}

impl TokenBucket {
    fn new(rate: f64, burst: f64) -> TokenBucket {
        TokenBucket {
            rate: rate.max(1.0),
            burst: burst.max(1.0),
            tokens: burst.max(1.0),
            last: 0.0,
        }
    }

    /// Consume `cost` tokens at time `now_s`; returns the seconds the
    /// caller must wait before proceeding (0.0 when the bucket covers
    /// the cost).
    fn take(&mut self, cost: f64, now_s: f64) -> f64 {
        let dt = (now_s - self.last).max(0.0);
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        self.last = now_s;
        self.tokens -= cost;
        if self.tokens >= 0.0 {
            0.0
        } else {
            -self.tokens / self.rate
        }
    }
}

/// Per-source token buckets pacing background copies under the weighted
/// policy (no-op under binary). A copy of class `c` charges
/// `bytes / share_vs_foreground(c)` tokens against a bucket refilled at
/// the source's full egress byte rate — equivalent to pacing each class
/// at its weighted fair share of the source's egress.
#[derive(Debug)]
pub struct StagingPacer {
    /// None: pacing disabled (binary policy).
    buckets: Option<Vec<Mutex<TokenBucket>>>,
    weights: super::ClassWeights,
    /// Shared wall clock (monotonic origin for every bucket).
    t0: Instant,
}

/// Chunk size for paced copies: small enough that pacing sleeps are
/// fine-grained, large enough that syscall overhead stays negligible.
const PACE_CHUNK: usize = 256 * 1024;

impl StagingPacer {
    /// Pacer for `n` executors under the configured policy.
    /// `egress_bps` is the per-executor egress bandwidth (bits/sec).
    pub fn new(n: usize, egress_bps: f64, cfg: &TransferConfig) -> StagingPacer {
        let buckets = match cfg.share_policy {
            SharePolicyKind::Binary => None,
            SharePolicyKind::Weighted => {
                let rate = (egress_bps / 8.0).max(1.0);
                Some(
                    (0..n)
                        .map(|_| Mutex::new(TokenBucket::new(rate, 2.0 * PACE_CHUNK as f64)))
                        .collect(),
                )
            }
        };
        StagingPacer {
            buckets,
            weights: cfg.class_weights,
            t0: Instant::now(),
        }
    }

    /// Whether this pacer actually paces (weighted policy).
    pub fn enabled(&self) -> bool {
        self.buckets.is_some()
    }

    /// Seconds a copy chunk of `bytes` from `src` under `class` must
    /// wait before proceeding (0.0 when pacing is off or the bucket
    /// covers it).
    pub fn wait_s(&self, src: ExecutorId, class: TransferClass, bytes: u64) -> f64 {
        let Some(buckets) = &self.buckets else {
            return 0.0;
        };
        let Some(bucket) = buckets.get(src) else {
            return 0.0;
        };
        let share = self.weights.share_vs_foreground(class).max(1e-6);
        let cost = bytes as f64 / share;
        let now_s = self.t0.elapsed().as_secs_f64();
        bucket.lock().unwrap().take(cost, now_s)
    }
}

/// The live driver's transfer plane: the share policy fed by real
/// byte-level egress accounting ([`EgressLedger`]).
pub struct LiveTransferPlane {
    ctl: AdmissionController,
    ledger: Arc<EgressLedger>,
}

impl LiveTransferPlane {
    /// Plane under the configured share policy, reading utilization from
    /// the shared egress ledger.
    pub fn new(cfg: &TransferConfig, ledger: Arc<EgressLedger>) -> Self {
        LiveTransferPlane {
            ctl: AdmissionController::with_policy(build_share_policy(cfg)),
            ledger,
        }
    }

    /// Measured egress utilization of one executor (for diagnostics).
    pub fn source_utilization(&self, exec: ExecutorId) -> f64 {
        self.ledger.utilization(exec)
    }
}

impl TransferPlane for LiveTransferPlane {
    fn submit(&mut self, req: TransferRequest) -> Admission {
        if !req.class.is_background() {
            return Admission::Start;
        }
        let util = self.ledger.utilization(req.src);
        self.ctl.offer(req, util)
    }

    fn readmit(&mut self) -> Vec<TransferRequest> {
        let ledger = &self.ledger;
        self.ctl.readmit(|e| ledger.utilization(e))
    }

    fn executor_released(&mut self, exec: ExecutorId) -> Vec<TransferRequest> {
        self.ctl.executor_released(exec)
    }

    fn deferred_len(&self) -> usize {
        self.ctl.deferred_len()
    }

    fn stats(&self) -> TransferStats {
        self.ctl.stats()
    }
}

/// The live data path: copy a source file into an executor's cache
/// directory, returning the bytes moved. Every cache-bound copy in the
/// live driver (peer fetch, persistent-storage fetch, staging) funnels
/// through here so all byte movement shares one accounted path.
pub fn copy_into_cache(src: &Path, dst: &Path) -> std::io::Result<u64> {
    std::fs::copy(src, dst)
}

/// Paced variant for background staging: the copy proceeds in
/// [`PACE_CHUNK`] chunks, each cleared through the source's token bucket
/// first, so a staging copy moves at its class's fair share of the
/// source's egress instead of hammering it (no-op pacing under the
/// binary policy — the pacer returns zero waits).
pub fn copy_into_cache_paced(
    src: &Path,
    dst: &Path,
    pacer: &StagingPacer,
    source: ExecutorId,
    class: TransferClass,
) -> std::io::Result<u64> {
    if !pacer.enabled() {
        return copy_into_cache(src, dst);
    }
    use std::io::{Read, Write};
    let mut input = std::fs::File::open(src)?;
    let mut output = std::fs::File::create(dst)?;
    let mut buf = vec![0u8; PACE_CHUNK];
    let mut total = 0u64;
    loop {
        let n = input.read(&mut buf)?;
        if n == 0 {
            break;
        }
        // Sleep the full debt: capping it would floor the copy at one
        // chunk per cap-interval and overrun the class's share on slow
        // links. The wait per chunk is bounded by chunk/(share·egress),
        // i.e. the transfer time the pacing is emulating.
        let wait = pacer.wait_s(source, class, n as u64);
        if wait > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(wait));
        }
        output.write_all(&buf[..n])?;
        total += n as u64;
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::object::ObjectId;
    use crate::transfer::ClassWeights;

    fn staging(obj: u64, src: usize) -> TransferRequest {
        TransferRequest {
            class: TransferClass::Staging,
            obj: ObjectId(obj),
            src,
            dst: 7,
            bytes: 100,
        }
    }

    fn plane(n: usize, budget: f64, egress_bps: f64) -> (LiveTransferPlane, Arc<EgressLedger>) {
        let ledger = Arc::new(EgressLedger::new(n, egress_bps));
        let cfg = TransferConfig {
            staging_budget: budget,
            ..TransferConfig::default()
        };
        (LiveTransferPlane::new(&cfg, ledger.clone()), ledger)
    }

    #[test]
    fn ledger_backlog_gates_admission() {
        // 8 Mb/s egress: 1 MB in flight = 1 s of backlog = saturated.
        let (mut p, ledger) = plane(4, 0.5, 8e6);
        ledger.begin(0, 1_000_000);
        assert!((ledger.utilization(0) - 1.0).abs() < 1e-9);
        assert_eq!(ledger.utilization(1), 0.0);
        assert_eq!(p.submit(staging(1, 0)), Admission::Defer);
        assert_eq!(p.submit(staging(2, 1)), Admission::Start);
        // Source 0 drains; the deferred transfer comes back.
        ledger.end(0, 1_000_000);
        assert_eq!(ledger.inflight_bytes(0), 0);
        let back = p.readmit();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].obj, ObjectId(1));
        assert_eq!(p.deferred_len(), 0);
    }

    #[test]
    fn ledger_guard_releases_on_drop_and_never_underflows() {
        let ledger = Arc::new(EgressLedger::new(2, 8e6));
        {
            let _g = EgressGuard::new(ledger.clone(), 1, 500_000);
            assert_eq!(ledger.inflight_bytes(1), 500_000);
            assert!((ledger.utilization(1) - 0.5).abs() < 1e-9);
        }
        assert_eq!(ledger.inflight_bytes(1), 0);
        // Out-of-range executors and double-ends are harmless.
        ledger.begin(99, 10);
        ledger.end(0, 10);
        assert_eq!(ledger.inflight_bytes(0), 0);
        assert_eq!(ledger.utilization(99), 0.0);
    }

    #[test]
    fn unknown_executor_is_idle_and_release_cancels() {
        let (mut p, ledger) = plane(4, 0.5, 8e6);
        assert_eq!(p.submit(staging(1, 42)), Admission::Start);
        ledger.begin(3, u64::MAX / 2);
        assert_eq!(p.submit(staging(2, 3)), Admission::Defer);
        let cancelled = p.executor_released(3);
        assert_eq!(cancelled.len(), 1);
        assert_eq!(p.stats().cancelled, 1);
        assert_eq!(p.deferred_len(), 0);
    }

    #[test]
    fn token_bucket_paces_at_rate_after_burst() {
        // 1000 B/s, burst 1000: the first 1000 tokens are free, then each
        // 500-token take costs 0.5 s of waiting.
        let mut b = TokenBucket::new(1000.0, 1000.0);
        assert_eq!(b.take(1000.0, 0.0), 0.0);
        let w1 = b.take(500.0, 0.0);
        assert!((w1 - 0.5).abs() < 1e-9, "w1={w1}");
        // After the debt is paid (t=0.5) another take waits again.
        let w2 = b.take(500.0, 0.5);
        assert!((w2 - 0.5).abs() < 1e-9, "w2={w2}");
        // A long idle gap refills only to the burst cap.
        let w3 = b.take(2000.0, 100.0);
        assert!((w3 - 1.0).abs() < 1e-9, "burst-capped refill: w3={w3}");
    }

    #[test]
    fn pacer_charges_by_class_share_and_binary_is_free() {
        let weighted = TransferConfig {
            share_policy: SharePolicyKind::Weighted,
            staging_budget: 1.0,
            class_weights: ClassWeights::default(),
        };
        // 8 Mb/s egress = 1e6 B/s bucket rate; burst 512 KiB.
        let p = StagingPacer::new(2, 8e6, &weighted);
        assert!(p.enabled());
        // Drain bucket 0's burst (104857 bytes at 20% share ≈ the burst),
        // then a 100 KB staging chunk costs 500 KB of tokens = ~0.5 s
        // (less whatever refilled between the two calls — tolerate CI
        // scheduling delay, but the wait must stay well above zero).
        let _ = p.wait_s(0, TransferClass::Staging, 104_857);
        let w = p.wait_s(0, TransferClass::Staging, 100_000);
        assert!(w > 0.25 && w <= 0.5 + 1e-6, "staging wait {w}");
        // Fresh bucket: the same 100 KB staging chunk fits the burst
        // (500 KB of tokens ≤ 512 KiB) — no wait …
        assert_eq!(p.wait_s(1, TransferClass::Staging, 100_000), 0.0);
        // … while prestage (share 0.1/1.1 ≈ 9%) pays ~11x the bytes and
        // must wait.
        let w_pre = p.wait_s(1, TransferClass::Prestage, 100_000);
        assert!(w_pre > 0.5, "prestage wait {w_pre}");
        // Binary policy: pacing disabled entirely.
        let b = StagingPacer::new(2, 8e6, &TransferConfig::default());
        assert!(!b.enabled());
        assert_eq!(b.wait_s(0, TransferClass::Staging, u64::MAX / 2), 0.0);
    }

    #[test]
    fn copy_into_cache_moves_real_bytes() {
        let dir = std::env::temp_dir().join(format!("dd_xfer_copy_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let src = dir.join("src.bin");
        let dst = dir.join("dst.bin");
        std::fs::write(&src, vec![7u8; 4096]).unwrap();
        let n = copy_into_cache(&src, &dst).unwrap();
        assert_eq!(n, 4096);
        assert_eq!(std::fs::read(&dst).unwrap().len(), 4096);
        // The paced variant moves identical bytes (binary pacer: no-op
        // path; weighted pacer: chunked path — both byte-exact).
        let b = StagingPacer::new(2, 8e6, &TransferConfig::default());
        let dst2 = dir.join("dst2.bin");
        let n = copy_into_cache_paced(&src, &dst2, &b, 0, TransferClass::Staging).unwrap();
        assert_eq!(n, 4096);
        let weighted = TransferConfig {
            share_policy: SharePolicyKind::Weighted,
            staging_budget: 1.0,
            class_weights: ClassWeights::default(),
        };
        // Generous rate: the 4 KB fits in the burst, so no sleeping.
        let w = StagingPacer::new(2, 8e9, &weighted);
        let dst3 = dir.join("dst3.bin");
        let n = copy_into_cache_paced(&src, &dst3, &w, 0, TransferClass::Staging).unwrap();
        assert_eq!(n, 4096);
        assert_eq!(std::fs::read(&dst3).unwrap(), std::fs::read(&src).unwrap());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
