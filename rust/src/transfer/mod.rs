//! The metered transfer plane: every byte movement carries a class, and
//! background movement is admission-controlled.
//!
//! Before this subsystem existed, replication staging shared the peer
//! path with foreground task fetches *unmetered*: a burst of staging
//! transfers could halve the bandwidth a task's input fetch saw, which
//! inverts the point of data diffusion (replication exists to *help*
//! foreground work — the companion paper arXiv:0808.3535 is explicit
//! that data-aware scheduling only wins once data movement is accounted
//! against the shared links it crosses).
//!
//! ## The class lattice
//!
//! Every transfer carries a [`TransferClass`], ordered
//!
//! ```text
//! Foreground  >  Staging  >  Prestage
//! ```
//!
//! * [`TransferClass::Foreground`] — a running task resolving an input
//!   (own-cache read, peer fetch, persistent-storage read) or writing an
//!   output. **Always admitted**: nothing in this plane may ever delay
//!   the task critical path.
//! * [`TransferClass::Staging`] — a demand-driven replication copy
//!   ([`crate::replication`]): useful soon, not urgent now.
//! * [`TransferClass::Prestage`] — warming a newly joined executor with
//!   the hottest objects: the most speculative traffic, re-admitted last.
//!
//! ## The share policy
//!
//! How the classes share a source executor's egress is a pluggable
//! [`SharePolicy`] (`[transfer] share_policy`, `--share-policy`), with
//! two implementations:
//!
//! * [`BinaryShare`] — PR 4's start-time-only rule, kept for
//!   comparison: background transfers are admitted only while the
//!   source's egress utilization is at or below the budget
//!   (`[transfer] staging_budget`, `--staging-budget`), and an admitted
//!   flow then competes 1:1 with foreground for its whole duration:
//!
//!   ```text
//!   admit(req)  ⇔  req.class == Foreground  ∨  util(req.src) ≤ budget
//!   ```
//!
//! * [`WeightedShare`] — weighted max-min fair sharing **for the whole
//!   flow lifetime**: every class carries a weight
//!   ([`ClassWeights`], default Foreground 1.0 / Staging 0.25 /
//!   Prestage 0.1) and contended capacity divides in weight proportion.
//!   In the simulator ([`crate::sim::flownet`]) the allocation is
//!   work-conserving — unused share is redistributed, so a lone staging
//!   flow still gets the whole link; the live plane approximates the
//!   same shares conservatively with token-bucket pacing at the class's
//!   fixed fair-share fraction (a paced copy never exceeds its share,
//!   even when the source is otherwise idle — the ledger cannot predict
//!   imminent foreground load). Deferral *composes* with weighting: the budget
//!   becomes a **hard cap** — below it background transfers are
//!   admitted-but-throttled; above it they defer exactly like the
//!   binary rule. The default budget of 1.0 never defers, so weighted
//!   mode is pure in-flight throttling out of the box.
//!
//! Either way a rejected transfer is *deferred*, not dropped: it waits
//! in a class-ordered queue and is re-admitted (`Staging` before
//! `Prestage`, FIFO within a class, at most one grant per source per
//! round so a drained source is not instantly re-saturated) as the
//! source's load falls back under budget. Deferred transfers whose
//! source or destination executor is released are cancelled and
//! reported so the replication manager can free its in-flight slot.
//! The binary policy with budget 1.0 (the default) disables the plane
//! entirely — utilization cannot exceed 1 and every weight is 1.0 —
//! reproducing the pre-metering behavior bit-for-bit.
//!
//! Two [`TransferPlane`] implementations carry the policy onto the two
//! execution substrates:
//!
//! * [`sim::SimTransferPlane`] wraps the [`crate::storage::testbed`]
//!   fair-share flow network ([`crate::sim::flownet`]): utilization is
//!   the measured rate-sum over the source's NIC-out and disk-read
//!   resources, and each flow starts with its class weight, so both
//!   admission and in-flight throttling react to the same contention
//!   physics the flows themselves obey.
//! * [`live::LiveTransferPlane`] wraps the live driver's cache-directory
//!   copy path: utilization is real **byte-level egress accounting**
//!   ([`live::EgressLedger`] — bytes in flight out of each source's
//!   cache, foreground and background alike, over the source's egress
//!   bandwidth), and background copies are paced by a per-source token
//!   bucket ([`live::StagingPacer`]) sized from the class weight — the
//!   live analog of the sim's weighted fair share.

pub mod live;
pub mod sim;

use crate::index::central::ExecutorId;
use crate::storage::object::ObjectId;

/// Priority class of one transfer. Order matters: `Foreground` preempts
/// nothing but is never deferred; `Staging` re-admits before `Prestage`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TransferClass {
    /// Join-time warm-up staging (most speculative, lowest priority).
    Prestage,
    /// Demand-driven replication staging.
    Staging,
    /// A task's own input fetch / output write (never deferred).
    Foreground,
}

impl TransferClass {
    /// All classes, in metrics-array order (see [`TransferClass::index`]).
    pub const ALL: [TransferClass; 3] = [
        TransferClass::Foreground,
        TransferClass::Staging,
        TransferClass::Prestage,
    ];

    /// Whether this class is subject to admission control.
    pub fn is_background(&self) -> bool {
        !matches!(self, TransferClass::Foreground)
    }

    /// Dense index for per-class counters: foreground 0, staging 1,
    /// prestage 2 (the order of [`TransferClass::ALL`]).
    pub fn index(&self) -> usize {
        match self {
            TransferClass::Foreground => 0,
            TransferClass::Staging => 1,
            TransferClass::Prestage => 2,
        }
    }

    /// Display label.
    pub fn label(&self) -> &'static str {
        match self {
            TransferClass::Foreground => "foreground",
            TransferClass::Staging => "staging",
            TransferClass::Prestage => "prestage",
        }
    }
}

/// Per-class fair-share weights for the weighted policy. Contended
/// capacity divides in weight proportion among the classes' flows, so
/// with the defaults one staging flow concedes 80% of a contended link
/// to a foreground fetch (1.0 vs 0.25) instead of splitting it evenly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassWeights {
    /// Foreground task I/O (the reference weight; keep at 1.0).
    pub foreground: f64,
    /// Demand-driven replication staging.
    pub staging: f64,
    /// Join-time warm-up prestaging.
    pub prestage: f64,
}

impl Default for ClassWeights {
    fn default() -> Self {
        ClassWeights {
            foreground: 1.0,
            staging: 0.25,
            prestage: 0.1,
        }
    }
}

impl ClassWeights {
    /// Weight of one class.
    pub fn of(&self, class: TransferClass) -> f64 {
        match class {
            TransferClass::Foreground => self.foreground,
            TransferClass::Staging => self.staging,
            TransferClass::Prestage => self.prestage,
        }
    }

    /// Unit weights (every class competes 1:1 — the binary policy's
    /// data-path behavior).
    pub const UNIT: ClassWeights = ClassWeights {
        foreground: 1.0,
        staging: 1.0,
        prestage: 1.0,
    };

    /// Fraction of a source's egress a background flow of `class` is
    /// entitled to against one contending foreground flow:
    /// `w / (w + w_fg)`. Sizes the live plane's token bucket.
    pub fn share_vs_foreground(&self, class: TransferClass) -> f64 {
        let w = self.of(class).max(1e-6);
        let fg = self.foreground.max(1e-6);
        w / (w + fg)
    }

    /// Parse `"fg,staging,prestage"` (e.g. `"1.0,0.25,0.1"`). Every
    /// weight must be a finite positive number — the same rule the
    /// config-file path enforces (an infinite weight would turn into a
    /// NaN share and invert the pacing it asked for).
    pub fn parse(s: &str) -> Option<ClassWeights> {
        let mut it = s.split(',').map(|p| p.trim().parse::<f64>().ok());
        let (fg, st, pre) = (it.next()??, it.next()??, it.next()??);
        let ok = [fg, st, pre].iter().all(|w| w.is_finite() && *w > 0.0);
        if it.next().is_some() || !ok {
            return None;
        }
        Some(ClassWeights {
            foreground: fg,
            staging: st,
            prestage: pre,
        })
    }
}

/// Share-policy selector (config / CLI `--share-policy binary|weighted`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SharePolicyKind {
    /// Start-time-only admission: defer over budget, compete 1:1 once
    /// admitted (PR 4's behavior; the default).
    #[default]
    Binary,
    /// Weighted max-min fair shares for the whole flow lifetime; the
    /// budget becomes a hard deferral cap.
    Weighted,
}

impl SharePolicyKind {
    /// Parse from config/CLI text.
    pub fn parse(s: &str) -> Option<SharePolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "binary" => Some(SharePolicyKind::Binary),
            "weighted" => Some(SharePolicyKind::Weighted),
            _ => None,
        }
    }

    /// Display label.
    pub fn label(&self) -> &'static str {
        match self {
            SharePolicyKind::Binary => "binary",
            SharePolicyKind::Weighted => "weighted",
        }
    }
}

/// How contending transfer classes share a source executor's egress:
/// the admission rule (may a background transfer *start* at this source
/// utilization?) plus the fair-share weight its flow carries once
/// running. One trait so deferral and weighting compose — the
/// [`AdmissionController`] owns the queue mechanics and delegates both
/// questions here.
pub trait SharePolicy: Send + std::fmt::Debug {
    /// Whether a *background* transfer of `class` may start while its
    /// source runs at `src_util` (foreground never consults this).
    fn admits(&self, class: TransferClass, src_util: f64) -> bool;

    /// Fair-share weight a flow of `class` carries on the data path.
    fn weight(&self, class: TransferClass) -> f64;

    /// The utilization level above which background transfers defer.
    fn budget(&self) -> f64;

    /// Class weights in force (unit for the binary policy).
    fn class_weights(&self) -> ClassWeights;

    /// Label for figures / CLI output.
    fn label(&self) -> &'static str;
}

/// PR 4's start-time-only policy: admit at or under budget, unit
/// weights once running.
#[derive(Debug, Clone, Copy)]
pub struct BinaryShare {
    budget: f64,
}

impl BinaryShare {
    /// Policy with the given utilization budget (clamped to [0, 1]).
    pub fn new(budget: f64) -> Self {
        BinaryShare {
            budget: budget.clamp(0.0, 1.0),
        }
    }
}

impl SharePolicy for BinaryShare {
    fn admits(&self, _class: TransferClass, src_util: f64) -> bool {
        src_util <= self.budget
    }

    fn weight(&self, _class: TransferClass) -> f64 {
        1.0
    }

    fn budget(&self) -> f64 {
        self.budget
    }

    fn class_weights(&self) -> ClassWeights {
        ClassWeights::UNIT
    }

    fn label(&self) -> &'static str {
        "binary"
    }
}

/// Weighted max-min fair sharing with a hard deferral cap: under the
/// cap background transfers are admitted-but-throttled at their class
/// weight; above it they defer like the binary rule (weighting and
/// deferral compose).
#[derive(Debug, Clone, Copy)]
pub struct WeightedShare {
    hard_cap: f64,
    weights: ClassWeights,
}

impl WeightedShare {
    /// Policy with the given hard cap (clamped to [0, 1]; 1.0 never
    /// defers) and class weights.
    pub fn new(hard_cap: f64, weights: ClassWeights) -> Self {
        WeightedShare {
            hard_cap: hard_cap.clamp(0.0, 1.0),
            weights,
        }
    }
}

impl SharePolicy for WeightedShare {
    fn admits(&self, _class: TransferClass, src_util: f64) -> bool {
        src_util <= self.hard_cap
    }

    fn weight(&self, class: TransferClass) -> f64 {
        self.weights.of(class)
    }

    fn budget(&self) -> f64 {
        self.hard_cap
    }

    fn class_weights(&self) -> ClassWeights {
        self.weights
    }

    fn label(&self) -> &'static str {
        "weighted"
    }
}

/// Build the configured share policy.
pub fn build_share_policy(cfg: &crate::config::TransferConfig) -> Box<dyn SharePolicy> {
    match cfg.share_policy {
        SharePolicyKind::Binary => Box::new(BinaryShare::new(cfg.staging_budget)),
        SharePolicyKind::Weighted => {
            Box::new(WeightedShare::new(cfg.staging_budget, cfg.class_weights))
        }
    }
}

/// One transfer offered to the plane: move `bytes` of `obj` from the
/// cache of `src` to the cache of `dst` under `class`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransferRequest {
    /// Priority class.
    pub class: TransferClass,
    /// Object being moved.
    pub obj: ObjectId,
    /// Source executor (whose egress the admission rule meters).
    pub src: ExecutorId,
    /// Destination executor.
    pub dst: ExecutorId,
    /// Bytes to move.
    pub bytes: u64,
}

/// Admission verdict for a submitted transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Start the data movement now.
    Start,
    /// Source over budget: queued for re-admission as load drains.
    Defer,
}

/// Lifetime admission-control counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TransferStats {
    /// Background transfers deferred at submission.
    pub deferred: u64,
    /// Previously deferred transfers re-admitted.
    pub readmitted: u64,
    /// Deferred transfers cancelled because their source or destination
    /// executor was released.
    pub cancelled: u64,
}

/// The class-aware admission controller shared by both plane
/// implementations. Pure control logic: the caller supplies source
/// utilization and performs the actual data movement; the admission
/// rule and the per-class data-path weights come from the configured
/// [`SharePolicy`].
#[derive(Debug)]
pub struct AdmissionController {
    /// How classes share egress: admission rule + flow weights.
    policy: Box<dyn SharePolicy>,
    /// Deferred background transfers, FIFO within each class.
    queue: Vec<TransferRequest>,
    stats: TransferStats,
}

impl AdmissionController {
    /// Binary controller with the given utilization budget (clamped to
    /// [0, 1]) — PR 4's behavior, the default policy.
    pub fn new(budget: f64) -> Self {
        AdmissionController::with_policy(Box::new(BinaryShare::new(budget)))
    }

    /// Controller over an explicit share policy (see
    /// [`build_share_policy`] for constructing one from config).
    pub fn with_policy(policy: Box<dyn SharePolicy>) -> Self {
        AdmissionController {
            policy,
            queue: Vec::new(),
            stats: TransferStats::default(),
        }
    }

    /// The utilization level above which background transfers defer.
    pub fn budget(&self) -> f64 {
        self.policy.budget()
    }

    /// The share policy in force.
    pub fn policy(&self) -> &dyn SharePolicy {
        self.policy.as_ref()
    }

    /// Data-path fair-share weight for a class under the policy.
    pub fn weight_of(&self, class: TransferClass) -> f64 {
        self.policy.weight(class)
    }

    /// Offer a transfer given its source's current egress utilization.
    /// Foreground is always admitted. Background is admitted at or under
    /// budget — unless an older transfer from the same source is still
    /// deferred, in which case the new one queues behind it (a fresh
    /// submission must not jump the FIFO order or sidestep the
    /// one-grant-per-source re-admission throttle).
    pub fn offer(&mut self, req: TransferRequest, src_util: f64) -> Admission {
        if !req.class.is_background() {
            return Admission::Start;
        }
        let queued_ahead = self.queue.iter().any(|r| r.src == req.src);
        if self.policy.admits(req.class, src_util) && !queued_ahead {
            Admission::Start
        } else {
            self.stats.deferred += 1;
            self.queue.push(req);
            Admission::Defer
        }
    }

    /// Re-admit deferred transfers whose source has drained to or below
    /// budget: `Staging` before `Prestage`, FIFO within a class, at most
    /// one grant per source per call (each grant will raise that
    /// source's utilization, so further grants wait for the next round).
    pub fn readmit(&mut self, mut src_util: impl FnMut(ExecutorId) -> f64) -> Vec<TransferRequest> {
        if self.queue.is_empty() {
            return Vec::new();
        }
        let mut admitted = Vec::new();
        let mut granted_src: Vec<ExecutorId> = Vec::new();
        for class in [TransferClass::Staging, TransferClass::Prestage] {
            let mut i = 0;
            while i < self.queue.len() {
                if self.queue[i].class != class || granted_src.contains(&self.queue[i].src) {
                    i += 1;
                    continue;
                }
                if self.policy.admits(class, src_util(self.queue[i].src)) {
                    let req = self.queue.remove(i);
                    granted_src.push(req.src);
                    self.stats.readmitted += 1;
                    admitted.push(req);
                } else {
                    i += 1;
                }
            }
        }
        admitted
    }

    /// An executor was released: cancel every deferred transfer touching
    /// it (as source or destination) and return them so the caller can
    /// free the replication manager's in-flight slots.
    pub fn executor_released(&mut self, exec: ExecutorId) -> Vec<TransferRequest> {
        let mut cancelled = Vec::new();
        self.queue.retain(|r| {
            if r.src == exec || r.dst == exec {
                cancelled.push(*r);
                false
            } else {
                true
            }
        });
        self.stats.cancelled += cancelled.len() as u64;
        cancelled
    }

    /// Transfers currently deferred.
    pub fn deferred_len(&self) -> usize {
        self.queue.len()
    }

    /// Lifetime counters.
    pub fn stats(&self) -> TransferStats {
        self.stats
    }
}

/// The transfer plane: class-tagged byte movement with admission
/// control. One implementation per execution substrate
/// ([`sim::SimTransferPlane`], [`live::LiveTransferPlane`]); the data
/// path is substrate-specific (flows vs file copies) and lives on the
/// concrete types, while this trait captures the control-plane contract
/// the drivers and tests program against.
pub trait TransferPlane {
    /// Submit a transfer. `Foreground` always returns
    /// [`Admission::Start`]; background classes may defer.
    fn submit(&mut self, req: TransferRequest) -> Admission;

    /// Deferred transfers whose source has drained under budget; the
    /// caller must start (or abandon) each returned request.
    fn readmit(&mut self) -> Vec<TransferRequest>;

    /// Cancel deferred transfers touching a released executor.
    fn executor_released(&mut self, exec: ExecutorId) -> Vec<TransferRequest>;

    /// Transfers currently deferred.
    fn deferred_len(&self) -> usize;

    /// Lifetime admission counters.
    fn stats(&self) -> TransferStats;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(class: TransferClass, obj: u64, src: usize, dst: usize) -> TransferRequest {
        TransferRequest {
            class,
            obj: ObjectId(obj),
            src,
            dst,
            bytes: 1024,
        }
    }

    #[test]
    fn foreground_is_always_admitted() {
        let mut c = AdmissionController::new(0.0);
        for util in [0.0, 0.5, 1.0] {
            assert_eq!(
                c.offer(req(TransferClass::Foreground, 1, 0, 1), util),
                Admission::Start,
                "foreground deferred at util {util}"
            );
        }
        assert_eq!(c.deferred_len(), 0);
        assert_eq!(c.stats().deferred, 0);
    }

    #[test]
    fn background_defers_over_budget_and_readmits_under() {
        let mut c = AdmissionController::new(0.5);
        assert_eq!(c.offer(req(TransferClass::Staging, 1, 0, 1), 0.4), Admission::Start);
        assert_eq!(c.offer(req(TransferClass::Staging, 2, 0, 1), 0.9), Admission::Defer);
        assert_eq!(c.deferred_len(), 1);
        // Still loaded: nothing comes back.
        assert!(c.readmit(|_| 0.9).is_empty());
        // Drained: the deferred staging is re-admitted.
        let back = c.readmit(|_| 0.1);
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].obj, ObjectId(2));
        assert_eq!(c.deferred_len(), 0);
        let s = c.stats();
        assert_eq!((s.deferred, s.readmitted, s.cancelled), (1, 1, 0));
    }

    #[test]
    fn budget_one_never_defers() {
        let mut c = AdmissionController::new(1.0);
        for i in 0..10 {
            assert_eq!(
                c.offer(req(TransferClass::Prestage, i, 0, 1), 1.0),
                Admission::Start
            );
        }
        assert_eq!(c.stats().deferred, 0);
    }

    #[test]
    fn staging_readmits_before_prestage_fifo_within_class() {
        let mut c = AdmissionController::new(0.2);
        // Deferred in mixed order, distinct sources so the one-grant-per-
        // source rule does not interfere.
        assert_eq!(c.offer(req(TransferClass::Prestage, 1, 0, 9), 0.9), Admission::Defer);
        assert_eq!(c.offer(req(TransferClass::Staging, 2, 1, 9), 0.9), Admission::Defer);
        assert_eq!(c.offer(req(TransferClass::Staging, 3, 2, 9), 0.9), Admission::Defer);
        let back = c.readmit(|_| 0.0);
        let classes: Vec<TransferClass> = back.iter().map(|r| r.class).collect();
        assert_eq!(
            classes,
            vec![TransferClass::Staging, TransferClass::Staging, TransferClass::Prestage]
        );
        assert_eq!(back[0].obj, ObjectId(2), "FIFO within the staging class");
    }

    #[test]
    fn fresh_submissions_queue_behind_deferred_same_source_transfers() {
        let mut c = AdmissionController::new(0.5);
        assert_eq!(c.offer(req(TransferClass::Staging, 1, 0, 8), 0.9), Admission::Defer);
        // Source drained, but an older transfer is still queued: the new
        // one must not jump it.
        assert_eq!(c.offer(req(TransferClass::Staging, 2, 0, 9), 0.1), Admission::Defer);
        // A different (idle) source is unaffected.
        assert_eq!(c.offer(req(TransferClass::Staging, 3, 1, 9), 0.1), Admission::Start);
        let back = c.readmit(|_| 0.0);
        assert_eq!(back.len(), 1, "one grant per source per round");
        assert_eq!(back[0].obj, ObjectId(1), "oldest first");
        assert_eq!(c.readmit(|_| 0.0)[0].obj, ObjectId(2));
    }

    #[test]
    fn one_grant_per_source_per_round() {
        let mut c = AdmissionController::new(0.2);
        assert_eq!(c.offer(req(TransferClass::Staging, 1, 0, 8), 0.9), Admission::Defer);
        assert_eq!(c.offer(req(TransferClass::Staging, 2, 0, 9), 0.9), Admission::Defer);
        let back = c.readmit(|_| 0.0);
        assert_eq!(back.len(), 1, "same source: one grant per round");
        assert_eq!(back[0].obj, ObjectId(1));
        let back = c.readmit(|_| 0.0);
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].obj, ObjectId(2));
    }

    #[test]
    fn released_executor_cancels_touching_transfers() {
        let mut c = AdmissionController::new(0.0);
        assert_eq!(c.offer(req(TransferClass::Staging, 1, 3, 5), 0.9), Admission::Defer);
        assert_eq!(c.offer(req(TransferClass::Staging, 2, 5, 7), 0.9), Admission::Defer);
        assert_eq!(c.offer(req(TransferClass::Prestage, 3, 1, 2), 0.9), Admission::Defer);
        let cancelled = c.executor_released(5);
        assert_eq!(cancelled.len(), 2, "src==5 and dst==5 both cancelled");
        assert_eq!(c.deferred_len(), 1);
        assert_eq!(c.stats().cancelled, 2);
        // The survivor is untouched and still re-admittable.
        assert_eq!(c.readmit(|_| 0.0).len(), 1);
    }

    #[test]
    fn class_weights_parse_and_share() {
        let w = ClassWeights::parse("1.0, 0.25,0.1").unwrap();
        assert_eq!(w, ClassWeights::default());
        assert!(ClassWeights::parse("1,0.25").is_none(), "needs 3 fields");
        assert!(ClassWeights::parse("1,0,0.1").is_none(), "weights > 0");
        assert!(ClassWeights::parse("1,inf,0.1").is_none(), "weights finite");
        assert!(ClassWeights::parse("1,0.25,0.1,9").is_none(), "extra field");
        assert_eq!(w.of(TransferClass::Foreground), 1.0);
        assert_eq!(w.of(TransferClass::Staging), 0.25);
        // Against one foreground flow: 0.25 / 1.25 = 20% of egress.
        assert!((w.share_vs_foreground(TransferClass::Staging) - 0.2).abs() < 1e-12);
        assert_eq!(SharePolicyKind::parse("weighted"), Some(SharePolicyKind::Weighted));
        assert_eq!(SharePolicyKind::parse("Binary"), Some(SharePolicyKind::Binary));
        assert_eq!(SharePolicyKind::parse("fair"), None);
        assert_eq!(SharePolicyKind::Weighted.label(), "weighted");
    }

    #[test]
    fn binary_policy_has_unit_weights_weighted_has_class_weights() {
        let b = BinaryShare::new(0.5);
        for class in TransferClass::ALL {
            assert_eq!(b.weight(class), 1.0);
            assert!(b.admits(class, 0.5));
            assert!(!b.admits(class, 0.6));
        }
        let w = WeightedShare::new(1.0, ClassWeights::default());
        assert_eq!(w.weight(TransferClass::Foreground), 1.0);
        assert_eq!(w.weight(TransferClass::Staging), 0.25);
        assert_eq!(w.weight(TransferClass::Prestage), 0.1);
        // Hard cap 1.0: never defers — pure throttling.
        assert!(w.admits(TransferClass::Prestage, 1.0));
        assert_eq!(w.label(), "weighted");
        assert_eq!(b.label(), "binary");
    }

    #[test]
    fn weighted_policy_composes_deferral_with_throttling() {
        // Hard cap 0.5: under it background is admitted (the data path
        // throttles it via the class weight), above it it defers and
        // re-admits exactly like the binary queue.
        let mut c = AdmissionController::with_policy(Box::new(WeightedShare::new(
            0.5,
            ClassWeights::default(),
        )));
        assert_eq!(c.weight_of(TransferClass::Staging), 0.25);
        assert_eq!(c.offer(req(TransferClass::Staging, 1, 0, 1), 0.4), Admission::Start);
        assert_eq!(c.offer(req(TransferClass::Staging, 2, 0, 1), 0.9), Admission::Defer);
        assert_eq!(c.offer(req(TransferClass::Foreground, 3, 0, 1), 1.0), Admission::Start);
        assert!(c.readmit(|_| 0.9).is_empty(), "still over the hard cap");
        let back = c.readmit(|_| 0.2);
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].obj, ObjectId(2));
        assert!((c.budget() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn class_lattice_order() {
        assert!(TransferClass::Foreground > TransferClass::Staging);
        assert!(TransferClass::Staging > TransferClass::Prestage);
        assert!(!TransferClass::Foreground.is_background());
        assert!(TransferClass::Staging.is_background());
        assert!(TransferClass::Prestage.is_background());
        assert_eq!(TransferClass::Prestage.label(), "prestage");
    }
}
