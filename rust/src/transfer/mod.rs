//! The metered transfer plane: every byte movement carries a class, and
//! background movement is admission-controlled.
//!
//! Before this subsystem existed, replication staging shared the peer
//! path with foreground task fetches *unmetered*: a burst of staging
//! transfers could halve the bandwidth a task's input fetch saw, which
//! inverts the point of data diffusion (replication exists to *help*
//! foreground work — the companion paper arXiv:0808.3535 is explicit
//! that data-aware scheduling only wins once data movement is accounted
//! against the shared links it crosses).
//!
//! ## The class lattice
//!
//! Every transfer carries a [`TransferClass`], ordered
//!
//! ```text
//! Foreground  >  Staging  >  Prestage
//! ```
//!
//! * [`TransferClass::Foreground`] — a running task resolving an input
//!   (own-cache read, peer fetch, persistent-storage read) or writing an
//!   output. **Always admitted**: nothing in this plane may ever delay
//!   the task critical path.
//! * [`TransferClass::Staging`] — a demand-driven replication copy
//!   ([`crate::replication`]): useful soon, not urgent now.
//! * [`TransferClass::Prestage`] — warming a newly joined executor with
//!   the hottest objects: the most speculative traffic, re-admitted last.
//!
//! ## The admission rule
//!
//! Background transfers (`Staging`/`Prestage`) are admitted only while
//! the **source executor's egress utilization** is at or below the
//! configured budget (`[transfer] staging_budget`, `--staging-budget`):
//!
//! ```text
//! admit(req)  ⇔  req.class == Foreground  ∨  util(req.src) ≤ budget
//! ```
//!
//! A rejected transfer is *deferred*, not dropped: it waits in a
//! class-ordered queue and is re-admitted (`Staging` before `Prestage`,
//! FIFO within a class, at most one grant per source per round so a
//! drained source is not instantly re-saturated) as the source's load
//! falls back under budget. Deferred transfers whose source or
//! destination executor is released are cancelled and reported so the
//! replication manager can free its in-flight slot. The budget default
//! of 1.0 disables deferral entirely (utilization cannot exceed 1), so
//! admission control is opt-in per run.
//!
//! Two [`TransferPlane`] implementations carry the rule onto the two
//! execution substrates:
//!
//! * [`sim::SimTransferPlane`] wraps the [`crate::storage::testbed`]
//!   fair-share flow network ([`crate::sim::flownet`]): utilization is
//!   the measured rate-sum over the source's NIC-out and disk-read
//!   resources, so admission reacts to the same contention physics the
//!   flows themselves obey.
//! * [`live::LiveTransferPlane`] wraps the live driver's cache-directory
//!   copy path: utilization is the source executor's busy-slot fraction
//!   (a running task is doing foreground I/O), fed by the coordinator
//!   each loop.

pub mod live;
pub mod sim;

use crate::index::central::ExecutorId;
use crate::storage::object::ObjectId;

/// Priority class of one transfer. Order matters: `Foreground` preempts
/// nothing but is never deferred; `Staging` re-admits before `Prestage`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TransferClass {
    /// Join-time warm-up staging (most speculative, lowest priority).
    Prestage,
    /// Demand-driven replication staging.
    Staging,
    /// A task's own input fetch / output write (never deferred).
    Foreground,
}

impl TransferClass {
    /// Whether this class is subject to admission control.
    pub fn is_background(&self) -> bool {
        !matches!(self, TransferClass::Foreground)
    }

    /// Display label.
    pub fn label(&self) -> &'static str {
        match self {
            TransferClass::Foreground => "foreground",
            TransferClass::Staging => "staging",
            TransferClass::Prestage => "prestage",
        }
    }
}

/// One transfer offered to the plane: move `bytes` of `obj` from the
/// cache of `src` to the cache of `dst` under `class`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransferRequest {
    /// Priority class.
    pub class: TransferClass,
    /// Object being moved.
    pub obj: ObjectId,
    /// Source executor (whose egress the admission rule meters).
    pub src: ExecutorId,
    /// Destination executor.
    pub dst: ExecutorId,
    /// Bytes to move.
    pub bytes: u64,
}

/// Admission verdict for a submitted transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Start the data movement now.
    Start,
    /// Source over budget: queued for re-admission as load drains.
    Defer,
}

/// Lifetime admission-control counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TransferStats {
    /// Background transfers deferred at submission.
    pub deferred: u64,
    /// Previously deferred transfers re-admitted.
    pub readmitted: u64,
    /// Deferred transfers cancelled because their source or destination
    /// executor was released.
    pub cancelled: u64,
}

/// The class-aware admission controller shared by both plane
/// implementations. Pure control logic: the caller supplies source
/// utilization and performs the actual data movement.
#[derive(Debug)]
pub struct AdmissionController {
    /// Source egress-utilization budget in [0, 1]; 1.0 never defers.
    budget: f64,
    /// Deferred background transfers, FIFO within each class.
    queue: Vec<TransferRequest>,
    stats: TransferStats,
}

impl AdmissionController {
    /// Controller with the given utilization budget (clamped to [0, 1]).
    pub fn new(budget: f64) -> Self {
        AdmissionController {
            budget: budget.clamp(0.0, 1.0),
            queue: Vec::new(),
            stats: TransferStats::default(),
        }
    }

    /// The utilization budget in force.
    pub fn budget(&self) -> f64 {
        self.budget
    }

    /// Offer a transfer given its source's current egress utilization.
    /// Foreground is always admitted. Background is admitted at or under
    /// budget — unless an older transfer from the same source is still
    /// deferred, in which case the new one queues behind it (a fresh
    /// submission must not jump the FIFO order or sidestep the
    /// one-grant-per-source re-admission throttle).
    pub fn offer(&mut self, req: TransferRequest, src_util: f64) -> Admission {
        if !req.class.is_background() {
            return Admission::Start;
        }
        let queued_ahead = self.queue.iter().any(|r| r.src == req.src);
        if src_util <= self.budget && !queued_ahead {
            Admission::Start
        } else {
            self.stats.deferred += 1;
            self.queue.push(req);
            Admission::Defer
        }
    }

    /// Re-admit deferred transfers whose source has drained to or below
    /// budget: `Staging` before `Prestage`, FIFO within a class, at most
    /// one grant per source per call (each grant will raise that
    /// source's utilization, so further grants wait for the next round).
    pub fn readmit(&mut self, mut src_util: impl FnMut(ExecutorId) -> f64) -> Vec<TransferRequest> {
        if self.queue.is_empty() {
            return Vec::new();
        }
        let mut admitted = Vec::new();
        let mut granted_src: Vec<ExecutorId> = Vec::new();
        for class in [TransferClass::Staging, TransferClass::Prestage] {
            let mut i = 0;
            while i < self.queue.len() {
                if self.queue[i].class != class || granted_src.contains(&self.queue[i].src) {
                    i += 1;
                    continue;
                }
                if src_util(self.queue[i].src) <= self.budget {
                    let req = self.queue.remove(i);
                    granted_src.push(req.src);
                    self.stats.readmitted += 1;
                    admitted.push(req);
                } else {
                    i += 1;
                }
            }
        }
        admitted
    }

    /// An executor was released: cancel every deferred transfer touching
    /// it (as source or destination) and return them so the caller can
    /// free the replication manager's in-flight slots.
    pub fn executor_released(&mut self, exec: ExecutorId) -> Vec<TransferRequest> {
        let mut cancelled = Vec::new();
        self.queue.retain(|r| {
            if r.src == exec || r.dst == exec {
                cancelled.push(*r);
                false
            } else {
                true
            }
        });
        self.stats.cancelled += cancelled.len() as u64;
        cancelled
    }

    /// Transfers currently deferred.
    pub fn deferred_len(&self) -> usize {
        self.queue.len()
    }

    /// Lifetime counters.
    pub fn stats(&self) -> TransferStats {
        self.stats
    }
}

/// The transfer plane: class-tagged byte movement with admission
/// control. One implementation per execution substrate
/// ([`sim::SimTransferPlane`], [`live::LiveTransferPlane`]); the data
/// path is substrate-specific (flows vs file copies) and lives on the
/// concrete types, while this trait captures the control-plane contract
/// the drivers and tests program against.
pub trait TransferPlane {
    /// Submit a transfer. `Foreground` always returns
    /// [`Admission::Start`]; background classes may defer.
    fn submit(&mut self, req: TransferRequest) -> Admission;

    /// Deferred transfers whose source has drained under budget; the
    /// caller must start (or abandon) each returned request.
    fn readmit(&mut self) -> Vec<TransferRequest>;

    /// Cancel deferred transfers touching a released executor.
    fn executor_released(&mut self, exec: ExecutorId) -> Vec<TransferRequest>;

    /// Transfers currently deferred.
    fn deferred_len(&self) -> usize;

    /// Lifetime admission counters.
    fn stats(&self) -> TransferStats;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(class: TransferClass, obj: u64, src: usize, dst: usize) -> TransferRequest {
        TransferRequest {
            class,
            obj: ObjectId(obj),
            src,
            dst,
            bytes: 1024,
        }
    }

    #[test]
    fn foreground_is_always_admitted() {
        let mut c = AdmissionController::new(0.0);
        for util in [0.0, 0.5, 1.0] {
            assert_eq!(
                c.offer(req(TransferClass::Foreground, 1, 0, 1), util),
                Admission::Start,
                "foreground deferred at util {util}"
            );
        }
        assert_eq!(c.deferred_len(), 0);
        assert_eq!(c.stats().deferred, 0);
    }

    #[test]
    fn background_defers_over_budget_and_readmits_under() {
        let mut c = AdmissionController::new(0.5);
        assert_eq!(c.offer(req(TransferClass::Staging, 1, 0, 1), 0.4), Admission::Start);
        assert_eq!(c.offer(req(TransferClass::Staging, 2, 0, 1), 0.9), Admission::Defer);
        assert_eq!(c.deferred_len(), 1);
        // Still loaded: nothing comes back.
        assert!(c.readmit(|_| 0.9).is_empty());
        // Drained: the deferred staging is re-admitted.
        let back = c.readmit(|_| 0.1);
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].obj, ObjectId(2));
        assert_eq!(c.deferred_len(), 0);
        let s = c.stats();
        assert_eq!((s.deferred, s.readmitted, s.cancelled), (1, 1, 0));
    }

    #[test]
    fn budget_one_never_defers() {
        let mut c = AdmissionController::new(1.0);
        for i in 0..10 {
            assert_eq!(
                c.offer(req(TransferClass::Prestage, i, 0, 1), 1.0),
                Admission::Start
            );
        }
        assert_eq!(c.stats().deferred, 0);
    }

    #[test]
    fn staging_readmits_before_prestage_fifo_within_class() {
        let mut c = AdmissionController::new(0.2);
        // Deferred in mixed order, distinct sources so the one-grant-per-
        // source rule does not interfere.
        assert_eq!(c.offer(req(TransferClass::Prestage, 1, 0, 9), 0.9), Admission::Defer);
        assert_eq!(c.offer(req(TransferClass::Staging, 2, 1, 9), 0.9), Admission::Defer);
        assert_eq!(c.offer(req(TransferClass::Staging, 3, 2, 9), 0.9), Admission::Defer);
        let back = c.readmit(|_| 0.0);
        let classes: Vec<TransferClass> = back.iter().map(|r| r.class).collect();
        assert_eq!(
            classes,
            vec![TransferClass::Staging, TransferClass::Staging, TransferClass::Prestage]
        );
        assert_eq!(back[0].obj, ObjectId(2), "FIFO within the staging class");
    }

    #[test]
    fn fresh_submissions_queue_behind_deferred_same_source_transfers() {
        let mut c = AdmissionController::new(0.5);
        assert_eq!(c.offer(req(TransferClass::Staging, 1, 0, 8), 0.9), Admission::Defer);
        // Source drained, but an older transfer is still queued: the new
        // one must not jump it.
        assert_eq!(c.offer(req(TransferClass::Staging, 2, 0, 9), 0.1), Admission::Defer);
        // A different (idle) source is unaffected.
        assert_eq!(c.offer(req(TransferClass::Staging, 3, 1, 9), 0.1), Admission::Start);
        let back = c.readmit(|_| 0.0);
        assert_eq!(back.len(), 1, "one grant per source per round");
        assert_eq!(back[0].obj, ObjectId(1), "oldest first");
        assert_eq!(c.readmit(|_| 0.0)[0].obj, ObjectId(2));
    }

    #[test]
    fn one_grant_per_source_per_round() {
        let mut c = AdmissionController::new(0.2);
        assert_eq!(c.offer(req(TransferClass::Staging, 1, 0, 8), 0.9), Admission::Defer);
        assert_eq!(c.offer(req(TransferClass::Staging, 2, 0, 9), 0.9), Admission::Defer);
        let back = c.readmit(|_| 0.0);
        assert_eq!(back.len(), 1, "same source: one grant per round");
        assert_eq!(back[0].obj, ObjectId(1));
        let back = c.readmit(|_| 0.0);
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].obj, ObjectId(2));
    }

    #[test]
    fn released_executor_cancels_touching_transfers() {
        let mut c = AdmissionController::new(0.0);
        assert_eq!(c.offer(req(TransferClass::Staging, 1, 3, 5), 0.9), Admission::Defer);
        assert_eq!(c.offer(req(TransferClass::Staging, 2, 5, 7), 0.9), Admission::Defer);
        assert_eq!(c.offer(req(TransferClass::Prestage, 3, 1, 2), 0.9), Admission::Defer);
        let cancelled = c.executor_released(5);
        assert_eq!(cancelled.len(), 2, "src==5 and dst==5 both cancelled");
        assert_eq!(c.deferred_len(), 1);
        assert_eq!(c.stats().cancelled, 2);
        // The survivor is untouched and still re-admittable.
        assert_eq!(c.readmit(|_| 0.0).len(), 1);
    }

    #[test]
    fn class_lattice_order() {
        assert!(TransferClass::Foreground > TransferClass::Staging);
        assert!(TransferClass::Staging > TransferClass::Prestage);
        assert!(!TransferClass::Foreground.is_background());
        assert!(TransferClass::Staging.is_background());
        assert!(TransferClass::Prestage.is_background());
        assert_eq!(TransferClass::Prestage.label(), "prestage");
    }
}
