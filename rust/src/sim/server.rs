//! FIFO service-time queue — the GPFS metadata server model.
//!
//! The paper's Figure 5 shows the sandbox-wrapper configuration capping at
//! ~21 tasks/s on 64 nodes because every task serializes directory
//! create/symlink/remove operations through the shared file system's
//! metadata service. We model that service as a single FIFO server with a
//! fixed per-operation service time: an arrival at time `t` completes at
//! `max(t, server_free) + ops * service_time`.

/// Single FIFO server with deterministic service times.
#[derive(Debug, Clone)]
pub struct FifoServer {
    service_s: f64,
    free_at: f64,
    ops_served: u64,
    busy_time: f64,
}

impl FifoServer {
    /// A server with the given per-operation service time (seconds).
    pub fn new(service_s: f64) -> Self {
        FifoServer {
            service_s,
            free_at: 0.0,
            ops_served: 0,
            busy_time: 0.0,
        }
    }

    /// Enqueue `ops` operations arriving at time `now`; returns the
    /// absolute completion time.
    pub fn submit(&mut self, now: f64, ops: u32) -> f64 {
        let start = if now > self.free_at { now } else { self.free_at };
        let dur = ops as f64 * self.service_s;
        self.free_at = start + dur;
        self.ops_served += ops as u64;
        self.busy_time += dur;
        self.free_at
    }

    /// Enqueue work of an explicit duration (for op classes with a
    /// different cost than the server's default, e.g. directory-mutating
    /// wrapper ops vs plain opens — both share this one server).
    pub fn submit_secs(&mut self, now: f64, secs: f64) -> f64 {
        let start = if now > self.free_at { now } else { self.free_at };
        self.free_at = start + secs;
        self.ops_served += 1;
        self.busy_time += secs;
        self.free_at
    }

    /// Completion time without mutating state (for what-if scheduling).
    pub fn peek(&self, now: f64, ops: u32) -> f64 {
        let start = if now > self.free_at { now } else { self.free_at };
        start + ops as f64 * self.service_s
    }

    /// Time at which the server becomes idle.
    pub fn free_at(&self) -> f64 {
        self.free_at
    }

    /// Operations served so far.
    pub fn ops_served(&self) -> u64 {
        self.ops_served
    }

    /// Utilization over `[0, horizon]`.
    pub fn utilization(&self, horizon: f64) -> f64 {
        if horizon <= 0.0 {
            0.0
        } else {
            (self.busy_time / horizon).min(1.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_server_serves_immediately() {
        let mut s = FifoServer::new(0.01);
        assert!((s.submit(5.0, 1) - 5.01).abs() < 1e-12);
    }

    #[test]
    fn queueing_delays_later_arrivals() {
        let mut s = FifoServer::new(0.01);
        let t1 = s.submit(0.0, 1);
        let t2 = s.submit(0.0, 1); // arrives while busy
        assert!((t1 - 0.01).abs() < 1e-12);
        assert!((t2 - 0.02).abs() < 1e-12);
    }

    #[test]
    fn multi_op_batches() {
        let mut s = FifoServer::new(0.015);
        // The wrapper's 3 metadata ops: 45 ms per task, serialized.
        let t = s.submit(0.0, 3);
        assert!((t - 0.045).abs() < 1e-12);
        // 64 concurrent submitters -> last completes at 64*0.045 = 2.88 s,
        // i.e. ~22 tasks/s aggregate — the paper's 21 tasks/s cap.
        let mut s = FifoServer::new(0.015);
        let mut last = 0.0;
        for _ in 0..64 {
            last = s.submit(0.0, 3);
        }
        let rate = 64.0 / last;
        assert!((rate - 22.2).abs() < 0.5, "rate={rate}");
    }

    #[test]
    fn peek_does_not_mutate() {
        let mut s = FifoServer::new(0.01);
        s.submit(0.0, 1);
        let p = s.peek(0.0, 1);
        assert!((p - 0.02).abs() < 1e-12);
        assert!((s.free_at() - 0.01).abs() < 1e-12);
    }

    #[test]
    fn utilization_tracks_busy_fraction() {
        let mut s = FifoServer::new(0.5);
        s.submit(0.0, 1);
        assert!((s.utilization(1.0) - 0.5).abs() < 1e-12);
    }
}
