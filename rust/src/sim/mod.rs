//! Discrete-event simulation core.
//!
//! The paper's experiments run on a 162-node testbed we don't have; per
//! the substitution rule (DESIGN.md §3) we reproduce the *contention
//! shapes* with a discrete-event simulator:
//!
//! * [`engine`] — a minimal, allocation-lean DES. The event queue is a
//!   **calendar queue**: a ring of time-bucketed event lists with an
//!   overflow heap for far-future timers, giving O(1) amortized
//!   insert/pop at 10⁷–10⁸-event scales while popping in *exactly* the
//!   old binary heap's order (time, then insertion seq).
//! * [`flownet`] — a fluid flow network with **weighted max-min fair
//!   sharing** (progressive filling). Every data movement in the system
//!   (GPFS read, cache-to-cache transfer, local disk read/write) is a
//!   flow across one or more capacity-limited resources; saturation,
//!   linear local-disk scaling, and NIC limits all emerge from this one
//!   mechanism. Rates are recomputed **incrementally per connected
//!   component** of the flow ↔ resource graph: node-local churn costs
//!   O(component), not O(all flows), which is what lets a single
//!   process simulate ~10⁵ executors (`falkon sweep --figure scale`
//!   measures it).
//! * [`server`] — a FIFO service-time queue used for the GPFS metadata
//!   server (the resource that caps small-file and wrapper workloads).
//! * [`parallel`] — a **conservative-lookahead parallel engine** for
//!   multi-site federation runs: each site's world + queue advances on
//!   its own worker thread in barrier-synchronized rounds, executing up
//!   to `min(next event times) + lookahead` where the lookahead is the
//!   site's WAN latency floor from `Topology`. Cross-site interactions
//!   travel as timestamped messages with sender-derived ordering keys,
//!   so outcomes are bit-for-bit identical at every thread count (see
//!   the module docs for the serial-equivalence contract).
//!
//! Both hot structures are observationally identical to their simple
//! predecessors (same event streams, same rates — debug builds
//! cross-check the incremental filling against a full recompute), so
//! determinism and replay equivalence are preserved bit-for-bit.
//!
//! The same coordinator logic (scheduler/cache/index) runs unchanged in
//! live mode; only the substrate differs.

pub mod engine;
pub mod flownet;
pub mod parallel;
pub mod server;

pub use engine::{Engine, World};
pub use flownet::{FlowId, FlowNetwork, ResourceId};
pub use parallel::{OutMsg, ParallelEngine, SiteWorld};
pub use server::FifoServer;
