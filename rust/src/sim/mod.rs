//! Discrete-event simulation core.
//!
//! The paper's experiments run on a 162-node testbed we don't have; per
//! the substitution rule (DESIGN.md §3) we reproduce the *contention
//! shapes* with a discrete-event simulator:
//!
//! * [`engine`] — a minimal, allocation-lean DES: a time-ordered event
//!   heap dispatching into a user `World`.
//! * [`flownet`] — a fluid flow network with **max-min fair sharing**
//!   (progressive filling). Every data movement in the system (GPFS read,
//!   cache-to-cache transfer, local disk read/write) is a flow across one
//!   or more capacity-limited resources; saturation, linear local-disk
//!   scaling, and NIC limits all emerge from this one mechanism.
//! * [`server`] — a FIFO service-time queue used for the GPFS metadata
//!   server (the resource that caps small-file and wrapper workloads).
//!
//! The same coordinator logic (scheduler/cache/index) runs unchanged in
//! live mode; only the substrate differs.

pub mod engine;
pub mod flownet;
pub mod server;

pub use engine::{Engine, World};
pub use flownet::{FlowId, FlowNetwork, ResourceId};
pub use server::FifoServer;
