//! Fluid flow network with weighted max-min fair sharing, recomputed
//! **incrementally per connected component**.
//!
//! Models every byte movement in the simulated system. A **resource** is a
//! capacity in bits/sec (GPFS aggregate read pool, a node's NIC-in, a
//! node's disk, ...). A **flow** is a transfer of `bytes` across a *set*
//! of resources; its instantaneous rate is bound by all of them.
//!
//! Rates follow **weighted max-min fairness** computed by progressive
//! filling: repeatedly find the bottleneck resource (smallest fair share
//! per unit weight), freeze the rates of the flows it carries at
//! `weight × share`, remove them, repeat. This is the standard fluid
//! approximation for TCP-like (or WFQ-shaped) sharing and is what makes
//! GPFS saturate at its aggregate cap while local-disk flows scale
//! linearly (each node's disk is a private resource).
//!
//! Weights are how the metered transfer plane ([`crate::transfer`])
//! bounds *in-flight* interference, not just admission: a background
//! staging flow started with weight 0.25 concedes 4/5 of a contended
//! link to a unit-weight foreground fetch, yet still runs — and the
//! allocation is **work-conserving**: share a bottlenecked flow cannot
//! use (because another resource binds it first) is redistributed to the
//! remaining flows, so capacity never idles while demand exists. With
//! every weight at 1.0 (the [`FlowSpec`] default) the arithmetic reduces
//! bit-for-bit to the classic unweighted fair share.
//!
//! Flows are started through one entry point: build a [`FlowSpec`]
//! (`FlowSpec::new(bytes).weight(w).over(&resources)`) and hand it to
//! [`FlowNetwork::start`]. The resource slice is copied into a pooled
//! vector, so the hot path allocates nothing in steady state.
//!
//! ## The incremental / component model
//!
//! Max-min rates are *memoryless*: they depend only on the current
//! membership, weights, and capacities — and a flow's rate can only
//! change when something changes in its **connected component** of the
//! flow ↔ resource bipartite graph. So every mutation (start, finish,
//! capacity change) marks the resources it touches dirty, floods out to
//! the affected component union, and re-runs progressive filling over
//! *that union only*, leaving every other component's frozen rates —
//! and their scheduled completions — untouched. On the paper's
//! workloads most traffic is node-local (one disk resource, a handful
//! of flows), so a start/finish costs O(component) instead of
//! O(all flows), which is what lets the simulator reach 10⁵ executors.
//!
//! Flow progress is materialized lazily: each flow carries the time
//! `t_sync` at which its `remaining_bits` was last true, and is only
//! advanced when its own rate is about to change (or it is removed).
//! Completions feed a lazy min-heap ordered by `(time, flow id)`;
//! entries are invalidated by a per-flow epoch stamped at each refill,
//! so [`FlowNetwork::next_completion`] preserves the exact historical
//! tie-break (earliest time, then smallest id) without rescanning flows.
//!
//! In debug builds every refill cross-checks the incremental rates
//! against a from-scratch filling over the whole network. The two are
//! bit-identical except when ratios in *different* components straddle
//! the filling's 1e-9 bottleneck tolerance (a measure-zero near-tie),
//! hence the tiny absolute + relative allowance in the check.
//!
//! The driver couples this to the DES by asking for the next completion
//! time after every membership change and re-scheduling its completion
//! event (with a version counter to invalidate stale events).
//!
//! Storage is a **slab** (`Vec<Option<Flow>>` + free list): flow churn is
//! the hottest operation in big simulations and profiling showed hash
//! lookups inside the rate recomputation dominating wall time. Slab
//! indexing is branch-cheap and the iteration order is deterministic.
//! Per-resource member lists give O(1) unlink on completion, and the
//! per-flow resource/position vectors are recycled through small pools
//! so steady-state churn allocates nothing.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Identifies a capacity resource.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResourceId(pub u32);

/// Identifies an active flow: `(generation << 32) | slot`. Generations
/// make stale ids detectable after slot reuse.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FlowId(pub u64);

impl FlowId {
    #[inline]
    fn slot(self) -> usize {
        (self.0 & 0xFFFF_FFFF) as usize
    }
}

/// Description of a flow to start: size, fair-share weight, and the
/// resource set it crosses. The single entry point for every byte
/// movement in the simulator:
///
/// ```
/// # use datadiffusion::sim::flownet::{FlowNetwork, FlowSpec};
/// let mut net = FlowNetwork::new();
/// let disk = net.add_resource(470e6);
/// let nic = net.add_resource(1e9);
/// // Unit-weight foreground fetch across disk + NIC.
/// net.start(0.0, FlowSpec::new(100 << 20).over(&[disk, nic]));
/// // Background staging at a quarter of the fair share.
/// net.start(0.0, FlowSpec::new(100 << 20).weight(0.25).over(&[disk]));
/// ```
///
/// The weight defaults to 1.0 (classic unweighted max-min); on every
/// contended resource a flow receives capacity in proportion to its
/// weight among the contending flows. Non-finite weights fall back to
/// 1.0 and non-positive ones are clamped to a positive floor — a zero
/// weight would starve the flow forever and stall the DES.
#[derive(Debug, Clone, Copy)]
pub struct FlowSpec<'a> {
    bytes: u64,
    weight: f64,
    resources: &'a [ResourceId],
}

impl<'a> FlowSpec<'a> {
    /// A unit-weight flow of `bytes` crossing no resources yet; route it
    /// with [`FlowSpec::over`] before starting it.
    pub fn new(bytes: u64) -> FlowSpec<'static> {
        FlowSpec { bytes, weight: 1.0, resources: &[] }
    }

    /// Set the fair-share weight (1.0 = classic max-min; the transfer
    /// plane's background classes run below 1.0).
    #[must_use]
    pub fn weight(mut self, weight: f64) -> Self {
        self.weight = weight;
        self
    }

    /// Set the resource set the flow crosses.
    #[must_use]
    pub fn over<'b>(self, resources: &'b [ResourceId]) -> FlowSpec<'b> {
        FlowSpec { bytes: self.bytes, weight: self.weight, resources }
    }
}

#[derive(Debug, Clone)]
struct Resource {
    capacity_bps: f64,
}

#[derive(Debug, Clone)]
struct Flow {
    id: FlowId,
    resources: Vec<ResourceId>,
    /// `positions[k]` is this flow's index in `members[resources[k]]`,
    /// kept current under swap-removal so unlink is O(resources).
    positions: Vec<u32>,
    /// Bits left as of `t_sync` (materialized lazily).
    remaining_bits: f64,
    t_sync: f64,
    rate_bps: f64,
    /// Fair-share weight (1.0 = classic max-min; the transfer plane's
    /// background classes run below 1.0).
    weight: f64,
    /// Refill epoch of this flow's valid completion-heap entry.
    comp_epoch: u64,
}

/// Candidate completion, min-ordered by `(time, flow id)` — the same
/// tie-break the old full scan used. Stale entries (epoch mismatch or
/// dead flow) are skipped lazily on pop.
#[derive(Debug, Clone, Copy)]
struct CompEntry {
    t: f64,
    id: FlowId,
    epoch: u64,
}

impl PartialEq for CompEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for CompEntry {}
impl PartialOrd for CompEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for CompEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we need earliest-first.
        other
            .t
            .total_cmp(&self.t)
            .then_with(|| other.id.0.cmp(&self.id.0))
            .then_with(|| other.epoch.cmp(&self.epoch))
    }
}

/// The flow network. Time is advanced explicitly by the caller.
#[derive(Debug, Default)]
pub struct FlowNetwork {
    resources: Vec<Resource>,
    slots: Vec<Option<Flow>>,
    free: Vec<u32>,
    active: usize,
    next_gen: u32,
    last_advance: f64,
    /// Per-resource list of active flow slots crossing it.
    members: Vec<Vec<u32>>,
    /// Resources whose membership or capacity changed since the last
    /// refill (deduplicated via `dirty_mark`).
    dirty: Vec<u32>,
    dirty_mark: Vec<bool>,
    /// Lazy completion min-heap (see [`CompEntry`]).
    completions: BinaryHeap<CompEntry>,
    refill_epoch: u64,
    // Scratch buffers reused across refills; only affected entries are
    // ever written, and they are reset before the refill returns.
    res_seen: Vec<bool>,
    flow_seen: Vec<bool>,
    aff_res: Vec<u32>,
    aff_flows: Vec<u32>,
    scratch_cap: Vec<f64>,
    scratch_wsum: Vec<f64>,
    scratch_unfixed: Vec<u32>,
    scratch_loaded: Vec<u32>,
    /// Recycled per-flow vectors (steady-state churn allocates nothing).
    res_pool: Vec<Vec<ResourceId>>,
    pos_pool: Vec<Vec<u32>>,
}

/// A resource's weight-sum below this is treated as unloaded: exact for
/// unit weights (integral f64 subtraction leaves exactly 0.0) and absorbs
/// the last-ulp residue fractional weights can leave behind.
const WSUM_EPS: f64 = 1e-12;

/// Cap on the recycled-vector pools (a pool larger than the peak live
/// flow count is dead weight).
const POOL_CAP: usize = 4096;

impl FlowNetwork {
    /// Empty network.
    pub fn new() -> Self {
        FlowNetwork::default()
    }

    /// Register a resource with the given capacity (bits/sec).
    pub fn add_resource(&mut self, capacity_bps: f64) -> ResourceId {
        assert!(capacity_bps > 0.0, "resource capacity must be positive");
        self.resources.push(Resource { capacity_bps });
        self.members.push(Vec::new());
        self.dirty_mark.push(false);
        ResourceId((self.resources.len() - 1) as u32)
    }

    /// Change a resource's capacity (e.g. provisioned bandwidth changes).
    /// The new capacity applies from the last advance point, exactly as
    /// the old deferred recompute did.
    pub fn set_capacity(&mut self, r: ResourceId, capacity_bps: f64) {
        self.resources[r.0 as usize].capacity_bps = capacity_bps;
        self.mark_dirty(r.0 as usize);
        let t = self.last_advance;
        self.refill(t);
    }

    /// Start the flow described by `spec` at time `now`. A flow must
    /// cross at least one resource. The spec's resource slice is copied
    /// into a pooled vector, so steady-state churn allocates nothing.
    pub fn start(&mut self, now: f64, spec: FlowSpec<'_>) -> FlowId {
        let mut rs = self.res_pool.pop().unwrap_or_default();
        rs.clear();
        rs.extend_from_slice(spec.resources);
        let positions = self.pos_pool.pop().unwrap_or_default();
        self.start_flow_inner(now, rs, positions, spec.bytes, spec.weight)
    }

    fn start_flow_inner(
        &mut self,
        now: f64,
        resources: Vec<ResourceId>,
        mut positions: Vec<u32>,
        bytes: u64,
        weight: f64,
    ) -> FlowId {
        assert!(!resources.is_empty(), "flow needs at least one resource");
        #[cfg(debug_assertions)]
        for (i, r) in resources.iter().enumerate() {
            debug_assert!(
                !resources[..i].contains(r),
                "duplicate resource {r:?} in flow"
            );
        }
        let weight = if weight.is_finite() { weight.max(1e-6) } else { 1.0 };
        let t = self.touch(now);
        self.next_gen = self.next_gen.wrapping_add(1);
        let slot = match self.free.pop() {
            Some(s) => s as usize,
            None => {
                self.slots.push(None);
                self.flow_seen.push(false);
                self.slots.len() - 1
            }
        };
        let id = FlowId(((self.next_gen as u64) << 32) | slot as u64);
        positions.clear();
        for r in &resources {
            let i = r.0 as usize;
            self.members[i].push(slot as u32);
            positions.push((self.members[i].len() - 1) as u32);
            self.mark_dirty(i);
        }
        self.slots[slot] = Some(Flow {
            id,
            resources,
            positions,
            // A zero-byte flow (1-byte files exist in the paper's sweeps
            // once metadata dominates) still completes immediately; keep a
            // floor of one bit to avoid NaN rates.
            remaining_bits: (bytes as f64 * 8.0).max(1e-9),
            t_sync: t,
            rate_bps: 0.0,
            weight,
            comp_epoch: 0,
        });
        self.active += 1;
        self.refill(t);
        id
    }

    #[inline]
    fn get(&self, id: FlowId) -> Option<&Flow> {
        match self.slots.get(id.slot()) {
            Some(Some(f)) if f.id == id => Some(f),
            _ => None,
        }
    }

    #[inline]
    fn mark_dirty(&mut self, i: usize) {
        if !self.dirty_mark[i] {
            self.dirty_mark[i] = true;
            self.dirty.push(i as u32);
        }
    }

    /// Move the network clock forward (monotone) and return it.
    #[inline]
    fn touch(&mut self, now: f64) -> f64 {
        if now > self.last_advance {
            self.last_advance = now;
        }
        self.last_advance
    }

    /// Progress the network to time `now`. Rates are kept current
    /// eagerly and per-flow progress is materialized lazily (each flow
    /// carries its own `t_sync`), so this only moves the clock.
    pub fn advance_to(&mut self, now: f64) {
        self.touch(now);
    }

    /// The earliest (time, flow) completion given current rates, or None
    /// if no flows are active. Call after `advance_to(now)`.
    pub fn next_completion(&mut self, now: f64) -> Option<(f64, FlowId)> {
        // Rates are recomputed eagerly at every mutation, so `now` is no
        // longer needed; kept for API stability with the driver.
        let _ = now;
        while let Some(top) = self.completions.peek() {
            let live = match self.slots.get(top.id.slot()) {
                Some(Some(f)) => f.id == top.id && f.comp_epoch == top.epoch,
                _ => false,
            };
            if live {
                return Some((top.t, top.id));
            }
            self.completions.pop();
        }
        None
    }

    /// Remove a completed (or cancelled) flow. Returns remaining bytes
    /// (0 for a clean completion).
    pub fn remove_flow(&mut self, now: f64, id: FlowId) -> f64 {
        let t = self.touch(now);
        let slot = id.slot();
        let flow = match self.slots.get_mut(slot) {
            Some(opt @ Some(_)) if opt.as_ref().unwrap().id == id => opt.take().unwrap(),
            _ => panic!("unknown flow {id:?}"),
        };
        // Materialize the flow's progress up to t before it disappears.
        let dt = t - flow.t_sync;
        let remaining = if dt > 0.0 {
            (flow.remaining_bits - flow.rate_bps * dt).max(0.0)
        } else {
            flow.remaining_bits
        };
        // Unlink from every member list (swap-remove, fixing the moved
        // flow's back-pointer).
        for k in 0..flow.resources.len() {
            let ri = flow.resources[k].0 as usize;
            let pos = flow.positions[k] as usize;
            self.members[ri].swap_remove(pos);
            if pos < self.members[ri].len() {
                let moved = self.members[ri][pos] as usize;
                let moved_from = self.members[ri].len() as u32;
                let mf = self.slots[moved].as_mut().unwrap();
                for j in 0..mf.resources.len() {
                    if mf.resources[j].0 as usize == ri && mf.positions[j] == moved_from {
                        mf.positions[j] = pos as u32;
                        break;
                    }
                }
            }
            self.mark_dirty(ri);
        }
        self.free.push(slot as u32);
        self.active -= 1;
        let Flow {
            mut resources,
            mut positions,
            ..
        } = flow;
        resources.clear();
        positions.clear();
        if self.res_pool.len() < POOL_CAP {
            self.res_pool.push(resources);
        }
        if self.pos_pool.len() < POOL_CAP {
            self.pos_pool.push(positions);
        }
        self.refill(t);
        remaining / 8.0
    }

    /// Instantaneous utilization of a resource in [0, 1]: the sum of the
    /// fair-share rates of every flow crossing it over its capacity. The
    /// transfer plane's admission controller reads this to decide whether
    /// a source executor's egress can absorb background staging.
    pub fn utilization(&mut self, r: ResourceId) -> f64 {
        let i = r.0 as usize;
        let cap = self.resources[i].capacity_bps;
        let mut used = 0.0;
        for &s in &self.members[i] {
            used += self.slots[s as usize].as_ref().unwrap().rate_bps;
        }
        (used / cap).clamp(0.0, 1.0)
    }

    /// Instantaneous rate of a flow (bits/sec), for metrics.
    pub fn rate(&mut self, id: FlowId) -> f64 {
        self.get(id).map(|f| f.rate_bps).unwrap_or(0.0)
    }

    /// Resource set of a flow (testing / introspection).
    pub fn flow_resources(&self, id: FlowId) -> &[ResourceId] {
        self.get(id).map(|f| f.resources.as_slice()).unwrap_or(&[])
    }

    /// Fair-share weight of a flow (0.0 for a stale id).
    pub fn flow_weight(&self, id: FlowId) -> f64 {
        self.get(id).map(|f| f.weight).unwrap_or(0.0)
    }

    /// Capacity of a resource (testing / introspection).
    pub fn capacity(&self, r: ResourceId) -> f64 {
        self.resources[r.0 as usize].capacity_bps
    }

    /// Number of active flows.
    pub fn active_flows(&self) -> usize {
        self.active
    }

    /// Recompute weighted max-min fair rates over the connected
    /// components touching any dirty resource, by progressive filling.
    ///
    /// Each resource tracks the *weight sum* of its unfixed flows; the
    /// per-level bottleneck share is `capacity / weight_sum` (share per
    /// unit weight, the WFQ virtual-time rate) and a frozen flow gets
    /// `weight × share`. Freezing subtracts the flow's granted rate from
    /// every resource it crosses, so share it cannot use elsewhere is
    /// redistributed to the survivors — work-conserving by construction.
    /// With all weights at 1.0 the weight sums are exact integers and the
    /// arithmetic is bit-identical to the classic unweighted filling.
    ///
    /// The affected flow/resource sets are sorted ascending before the
    /// filling so the arithmetic visits them in slab order — the same
    /// order a full recompute restricted to this union would use.
    ///
    /// O(levels · component) — no hashing, no allocation (scratch
    /// buffers persist and are sparsely reset), no global scans.
    fn refill(&mut self, t: f64) {
        if self.dirty.is_empty() {
            return;
        }
        let nr = self.resources.len();
        if self.res_seen.len() < nr {
            self.res_seen.resize(nr, false);
            self.scratch_cap.resize(nr, 0.0);
            self.scratch_wsum.resize(nr, 0.0);
        }
        self.aff_res.clear();
        self.aff_flows.clear();
        // Seed the flood with the dirty resources…
        for &d in &self.dirty {
            let r = d as usize;
            self.dirty_mark[r] = false;
            if !self.res_seen[r] {
                self.res_seen[r] = true;
                self.aff_res.push(d);
            }
        }
        self.dirty.clear();
        // …and flood across the flow ↔ resource bipartite graph to the
        // union of the affected connected components.
        let mut qi = 0;
        while qi < self.aff_res.len() {
            let r = self.aff_res[qi] as usize;
            qi += 1;
            let mut mi = 0;
            while mi < self.members[r].len() {
                let s = self.members[r][mi] as usize;
                mi += 1;
                if self.flow_seen[s] {
                    continue;
                }
                self.flow_seen[s] = true;
                self.aff_flows.push(s as u32);
                let nres = self.slots[s].as_ref().unwrap().resources.len();
                for j in 0..nres {
                    let r2 = self.slots[s].as_ref().unwrap().resources[j].0;
                    if !self.res_seen[r2 as usize] {
                        self.res_seen[r2 as usize] = true;
                        self.aff_res.push(r2);
                    }
                }
            }
        }
        self.aff_flows.sort_unstable();
        self.aff_res.sort_unstable();
        // Materialize affected flows at t: their rates are about to
        // change, so their progress under the old rate ends here.
        for &fs in &self.aff_flows {
            let flow = self.slots[fs as usize].as_mut().unwrap();
            let dt = t - flow.t_sync;
            if dt > 0.0 {
                flow.remaining_bits = (flow.remaining_bits - flow.rate_bps * dt).max(0.0);
            }
            flow.t_sync = t;
        }
        // Progressive filling restricted to the affected subgraph.
        for &a in &self.aff_res {
            let i = a as usize;
            self.scratch_cap[i] = self.resources[i].capacity_bps;
            self.scratch_wsum[i] = 0.0;
        }
        for &fs in &self.aff_flows {
            let flow = self.slots[fs as usize].as_ref().unwrap();
            for r in &flow.resources {
                self.scratch_wsum[r.0 as usize] += flow.weight;
            }
        }
        self.scratch_unfixed.clear();
        self.scratch_unfixed.extend_from_slice(&self.aff_flows);
        self.scratch_loaded.clear();
        for &a in &self.aff_res {
            if self.scratch_wsum[a as usize] > WSUM_EPS {
                self.scratch_loaded.push(a);
            }
        }
        let cap = &mut self.scratch_cap;
        let wsum = &mut self.scratch_wsum;
        let mut n_unfixed = self.scratch_unfixed.len();
        while n_unfixed > 0 {
            // Bottleneck: min per-unit-weight share among loaded resources.
            let mut share = f64::INFINITY;
            let mut keep_loaded = 0usize;
            for k in 0..self.scratch_loaded.len() {
                let i = self.scratch_loaded[k] as usize;
                if wsum[i] > WSUM_EPS {
                    self.scratch_loaded[keep_loaded] = i as u32;
                    keep_loaded += 1;
                    let s = cap[i] / wsum[i];
                    if s < share {
                        share = s;
                    }
                }
            }
            self.scratch_loaded.truncate(keep_loaded);
            if !share.is_finite() {
                for &slot in &self.scratch_unfixed[..n_unfixed] {
                    self.slots[slot as usize].as_mut().unwrap().rate_bps = 0.0;
                }
                break;
            }
            // Freeze flows crossing a bottleneck resource at
            // `weight × share`, compacting survivors to the front.
            let mut keep = 0usize;
            for k in 0..n_unfixed {
                let slot = self.scratch_unfixed[k] as usize;
                let flow = self.slots[slot].as_mut().unwrap();
                let bottlenecked = flow.resources.iter().any(|r| {
                    let i = r.0 as usize;
                    wsum[i] > WSUM_EPS && (cap[i] / wsum[i]) <= share + 1e-9
                });
                if bottlenecked {
                    flow.rate_bps = flow.weight * share;
                    for r in &flow.resources {
                        let i = r.0 as usize;
                        cap[i] -= flow.weight * share;
                        wsum[i] -= flow.weight;
                    }
                } else {
                    self.scratch_unfixed[keep] = slot as u32;
                    keep += 1;
                }
            }
            debug_assert!(keep < n_unfixed, "progressive filling must shrink");
            n_unfixed = keep;
        }
        // New rates → new completion candidates, stamped with a fresh
        // epoch so older heap entries for these flows die.
        self.refill_epoch += 1;
        let epoch = self.refill_epoch;
        for &fs in &self.aff_flows {
            let s = fs as usize;
            self.flow_seen[s] = false;
            let flow = self.slots[s].as_mut().unwrap();
            flow.comp_epoch = epoch;
            if flow.rate_bps > 0.0 {
                let entry = CompEntry {
                    t: flow.t_sync + flow.remaining_bits / flow.rate_bps,
                    id: flow.id,
                    epoch,
                };
                self.completions.push(entry);
            }
        }
        for &a in &self.aff_res {
            self.res_seen[a as usize] = false;
        }
        // Keep the lazy heap from accumulating stale entries faster than
        // pops retire them.
        if self.completions.len() > 64 && self.completions.len() > 8 * self.active {
            let drained = std::mem::take(&mut self.completions);
            self.completions = drained
                .into_iter()
                .filter(|e| match self.slots.get(e.id.slot()) {
                    Some(Some(f)) => f.id == e.id && f.comp_epoch == e.epoch,
                    _ => false,
                })
                .collect();
        }
        #[cfg(debug_assertions)]
        self.assert_matches_full_recompute();
    }

    /// Debug-only cross-check: the incremental rates must match a
    /// from-scratch progressive filling over the whole network. The two
    /// are bit-identical unless bottleneck ratios in different components
    /// straddle the filling's 1e-9 tolerance, hence the tiny allowance.
    #[cfg(debug_assertions)]
    fn assert_matches_full_recompute(&self) {
        let nr = self.resources.len();
        let mut cap: Vec<f64> = self.resources.iter().map(|r| r.capacity_bps).collect();
        let mut wsum = vec![0.0f64; nr];
        let mut unfixed: Vec<u32> = Vec::new();
        for (slot, flow) in self.slots.iter().enumerate() {
            if let Some(flow) = flow {
                unfixed.push(slot as u32);
                for r in &flow.resources {
                    wsum[r.0 as usize] += flow.weight;
                }
            }
        }
        let mut rates = vec![0.0f64; self.slots.len()];
        let mut loaded: Vec<u32> = (0..nr as u32)
            .filter(|&i| wsum[i as usize] > WSUM_EPS)
            .collect();
        while !unfixed.is_empty() {
            loaded.retain(|&i| wsum[i as usize] > WSUM_EPS);
            let mut share = f64::INFINITY;
            for &i in &loaded {
                let s = cap[i as usize] / wsum[i as usize];
                if s < share {
                    share = s;
                }
            }
            if !share.is_finite() {
                for &s in &unfixed {
                    rates[s as usize] = 0.0;
                }
                break;
            }
            let mut keep = Vec::new();
            for &su in &unfixed {
                let flow = self.slots[su as usize].as_ref().unwrap();
                let bottlenecked = flow.resources.iter().any(|r| {
                    let i = r.0 as usize;
                    wsum[i] > WSUM_EPS && (cap[i] / wsum[i]) <= share + 1e-9
                });
                if bottlenecked {
                    rates[su as usize] = flow.weight * share;
                    for r in &flow.resources {
                        let i = r.0 as usize;
                        cap[i] -= flow.weight * share;
                        wsum[i] -= flow.weight;
                    }
                } else {
                    keep.push(su);
                }
            }
            debug_assert!(keep.len() < unfixed.len(), "progressive filling must shrink");
            unfixed = keep;
        }
        for (slot, flow) in self.slots.iter().enumerate() {
            if let Some(flow) = flow {
                let a = flow.rate_bps;
                let b = rates[slot];
                let tol = 1e-6 + 1e-9 * a.abs().max(b.abs());
                assert!(
                    a == b || (a - b).abs() <= tol,
                    "incremental rate diverged from full recompute: \
                     slot {slot} incremental {a} full {b}"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EPS: f64 = 1e-6;

    #[test]
    fn single_flow_gets_full_capacity() {
        let mut net = FlowNetwork::new();
        let r = net.add_resource(8e6); // 1 MB/s
        let f = net.start(0.0, FlowSpec::new(1_000_000).over(&[r]));
        let (t, id) = net.next_completion(0.0).unwrap();
        assert_eq!(id, f);
        assert!((t - 1.0).abs() < EPS, "t={t}");
    }

    #[test]
    fn two_flows_share_fairly() {
        let mut net = FlowNetwork::new();
        let r = net.add_resource(8e6);
        let _a = net.start(0.0, FlowSpec::new(1_000_000).over(&[r]));
        let _b = net.start(0.0, FlowSpec::new(1_000_000).over(&[r]));
        // Each gets half: 2 s for both.
        let (t, _) = net.next_completion(0.0).unwrap();
        assert!((t - 2.0).abs() < EPS, "t={t}");
    }

    #[test]
    fn flow_bound_by_tightest_resource() {
        let mut net = FlowNetwork::new();
        let wide = net.add_resource(80e6);
        let narrow = net.add_resource(8e6);
        let f = net.start(0.0, FlowSpec::new(1_000_000).over(&[wide, narrow]));
        assert!((net.rate(f) - 8e6).abs() < EPS);
    }

    #[test]
    fn max_min_textbook_example() {
        // Two resources: R0 cap 10, R1 cap 4 (bits/s).
        // Flow A uses {R0}, flow B uses {R0, R1}, flow C uses {R1}.
        // Progressive filling: R1 share = 2 -> B=C=2; then A gets 10-2=8.
        let mut net = FlowNetwork::new();
        let r0 = net.add_resource(10.0);
        let r1 = net.add_resource(4.0);
        let a = net.start(0.0, FlowSpec::new(1000).over(&[r0]));
        let b = net.start(0.0, FlowSpec::new(1000).over(&[r0, r1]));
        let c = net.start(0.0, FlowSpec::new(1000).over(&[r1]));
        assert!((net.rate(a) - 8.0).abs() < EPS, "a={}", net.rate(a));
        assert!((net.rate(b) - 2.0).abs() < EPS, "b={}", net.rate(b));
        assert!((net.rate(c) - 2.0).abs() < EPS, "c={}", net.rate(c));
    }

    #[test]
    fn conservation_no_resource_oversubscribed() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(17);
        let mut net = FlowNetwork::new();
        let rs: Vec<ResourceId> = (0..10)
            .map(|_| net.add_resource(rng.range_f64(1e6, 1e9)))
            .collect();
        let mut flows = Vec::new();
        for _ in 0..100 {
            let k = rng.range_u64(1, 3) as usize;
            let mut set: Vec<ResourceId> = Vec::new();
            for _ in 0..k {
                let r = rs[rng.index(rs.len())];
                if !set.contains(&r) {
                    set.push(r);
                }
            }
            flows.push(net.start(0.0, FlowSpec::new(rng.range_u64(1, 1_000_000)).over(&set)));
        }
        // Sum of rates per resource must not exceed its capacity.
        let mut usage = vec![0.0f64; 10];
        for &f in &flows {
            let rate = net.rate(f);
            assert!(rate > 0.0, "every flow must make progress");
            for r in net.flow_resources(f).to_vec() {
                usage[r.0 as usize] += rate;
            }
        }
        for (i, u) in usage.iter().enumerate() {
            let cap = net.capacity(ResourceId(i as u32));
            assert!(*u <= cap * (1.0 + 1e-6), "resource {i}: {u} > {cap}");
        }
    }

    #[test]
    fn completion_matches_analytic_two_phase() {
        // Flow A (2 MB) and B (1 MB) share 8 Mb/s. B finishes at t=2
        // (rate 4 Mb/s → 8 Mbit / 4 Mbps). A then speeds up: it has
        // 8 Mbit left at t=2, finishing at t=3.
        let mut net = FlowNetwork::new();
        let r = net.add_resource(8e6);
        let a = net.start(0.0, FlowSpec::new(2_000_000).over(&[r]));
        let b = net.start(0.0, FlowSpec::new(1_000_000).over(&[r]));
        let (t1, id1) = net.next_completion(0.0).unwrap();
        assert_eq!(id1, b);
        assert!((t1 - 2.0).abs() < EPS);
        let left = net.remove_flow(t1, b);
        assert!(left.abs() < 1e-3);
        let (t2, id2) = net.next_completion(t1).unwrap();
        assert_eq!(id2, a);
        assert!((t2 - 3.0).abs() < EPS, "t2={t2}");
    }

    #[test]
    fn local_disks_scale_linearly_gpfs_saturates() {
        // The paper's core observation as a unit test: n private disk
        // resources aggregate n×, a shared pool stays flat.
        for n in [8usize, 16, 64] {
            let mut net = FlowNetwork::new();
            let gpfs = net.add_resource(3.4e9);
            let mut disk_flows = Vec::new();
            let mut gpfs_flows = Vec::new();
            for _ in 0..n {
                let disk = net.add_resource(470e6);
                disk_flows.push(net.start(0.0, FlowSpec::new(100_000_000).over(&[disk])));
                gpfs_flows.push(net.start(0.0, FlowSpec::new(100_000_000).over(&[gpfs])));
            }
            let disk_agg: f64 = disk_flows.iter().map(|&f| net.rate(f)).sum();
            let gpfs_agg: f64 = gpfs_flows.iter().map(|&f| net.rate(f)).sum();
            assert!((disk_agg - n as f64 * 470e6).abs() < 1.0);
            assert!((gpfs_agg - 3.4e9).abs() < 1.0);
        }
    }

    #[test]
    fn utilization_tracks_fair_share_load() {
        let mut net = FlowNetwork::new();
        let wide = net.add_resource(10e6);
        let narrow = net.add_resource(4e6);
        assert_eq!(net.utilization(wide), 0.0);
        // One flow bound by the narrow resource: wide carries 4 of 10.
        let f = net.start(0.0, FlowSpec::new(1_000_000).over(&[wide, narrow]));
        assert!((net.utilization(narrow) - 1.0).abs() < EPS);
        assert!((net.utilization(wide) - 0.4).abs() < EPS);
        net.remove_flow(0.0, f);
        assert_eq!(net.utilization(narrow), 0.0);
    }

    #[test]
    fn weighted_flows_split_in_weight_proportion() {
        // Foreground (1.0) vs staging (0.25) on one 10 Mb/s link:
        // 8 Mb/s vs 2 Mb/s.
        let mut net = FlowNetwork::new();
        let r = net.add_resource(10e6);
        let fg = net.start(0.0, FlowSpec::new(1_000_000).over(&[r]));
        let bg = net.start(0.0, FlowSpec::new(1_000_000).weight(0.25).over(&[r]));
        assert!((net.rate(fg) - 8e6).abs() < EPS, "fg={}", net.rate(fg));
        assert!((net.rate(bg) - 2e6).abs() < EPS, "bg={}", net.rate(bg));
        assert_eq!(net.flow_weight(fg), 1.0);
        assert_eq!(net.flow_weight(bg), 0.25);
        // Completion times follow the weighted rates: bg (2 Mb/s over
        // 8 Mbit) would finish at t=4; fg at t=1, after which bg speeds
        // up to the full link. fg completes first.
        let (t, id) = net.next_completion(0.0).unwrap();
        assert_eq!(id, fg);
        assert!((t - 1.0).abs() < EPS, "t={t}");
    }

    #[test]
    fn weighted_sharing_is_work_conserving() {
        // A low-weight flow alone still gets the whole link (weights
        // scale shares among *contenders*, they are not absolute caps).
        let mut net = FlowNetwork::new();
        let r = net.add_resource(10e6);
        let bg = net.start(0.0, FlowSpec::new(1_000_000).weight(0.1).over(&[r]));
        assert!((net.rate(bg) - 10e6).abs() < EPS, "bg={}", net.rate(bg));
        // And share a bottlenecked-elsewhere flow cannot use is
        // redistributed: B (weight 1) is pinned to 1 Mb/s by a narrow
        // private link, so A (weight 0.25) takes the remaining 9 Mb/s.
        let mut net = FlowNetwork::new();
        let wide = net.add_resource(10e6);
        let narrow = net.add_resource(1e6);
        let a = net.start(0.0, FlowSpec::new(1_000_000).weight(0.25).over(&[wide]));
        let b = net.start(0.0, FlowSpec::new(1_000_000).over(&[wide, narrow]));
        assert!((net.rate(b) - 1e6).abs() < EPS, "b={}", net.rate(b));
        assert!((net.rate(a) - 9e6).abs() < EPS, "a={}", net.rate(a));
    }

    #[test]
    fn unit_weights_match_unweighted_filling_exactly() {
        // The FlowSpec default weight and an explicit `.weight(1.0)` must
        // be the same computation bit-for-bit (the binary share policy
        // relies on it).
        let build = |explicit: bool| {
            let mut net = FlowNetwork::new();
            let r0 = net.add_resource(10.0);
            let r1 = net.add_resource(4.0);
            let mk = |net: &mut FlowNetwork, rs: &[ResourceId]| {
                let spec = FlowSpec::new(1000);
                let spec = if explicit { spec.weight(1.0) } else { spec };
                net.start(0.0, spec.over(rs))
            };
            let a = mk(&mut net, &[r0]);
            let b = mk(&mut net, &[r0, r1]);
            let c = mk(&mut net, &[r1]);
            let rates = (net.rate(a), net.rate(b), net.rate(c));
            let next = net.next_completion(0.0).unwrap();
            (rates, next)
        };
        assert_eq!(build(false), build(true));
    }

    #[test]
    fn nonpositive_weight_is_clamped_not_starved() {
        let mut net = FlowNetwork::new();
        let r = net.add_resource(1e6);
        let f = net.start(0.0, FlowSpec::new(1_000).weight(0.0).over(&[r]));
        assert!(net.rate(f) > 0.0, "clamped weight must still progress");
        let (t, _) = net.next_completion(0.0).unwrap();
        assert!(t.is_finite());
    }

    #[test]
    fn zero_byte_flow_completes() {
        let mut net = FlowNetwork::new();
        let r = net.add_resource(1e6);
        let _f = net.start(0.0, FlowSpec::new(0).over(&[r]));
        let (t, _) = net.next_completion(0.0).unwrap();
        assert!(t < 1e-9);
    }

    #[test]
    fn slot_reuse_keeps_ids_distinct() {
        let mut net = FlowNetwork::new();
        let r = net.add_resource(1e6);
        let a = net.start(0.0, FlowSpec::new(100).over(&[r]));
        net.remove_flow(0.0, a);
        let b = net.start(0.0, FlowSpec::new(100).over(&[r]));
        assert_ne!(a, b, "generation must differ after slot reuse");
        assert_eq!(net.rate(a), 0.0, "stale id must read as inactive");
        assert!(net.rate(b) > 0.0);
        assert_eq!(net.active_flows(), 1);
    }

    #[test]
    fn disjoint_components_refill_independently() {
        // Churn in one component must not perturb another component's
        // frozen rates — not even by an ulp.
        let mut net = FlowNetwork::new();
        let r1 = net.add_resource(8e6);
        let r2 = net.add_resource(6e6);
        let a = net.start(0.0, FlowSpec::new(1_000_000).over(&[r1]));
        let b = net.start(0.0, FlowSpec::new(1_000_000).over(&[r1]));
        let rate_a = net.rate(a);
        let rate_b = net.rate(b);
        let (t0, id0) = net.next_completion(0.0).unwrap();
        // Heavy churn on the other component.
        let mut others = Vec::new();
        for i in 0..20 {
            others.push(net.start(0.1 * i as f64, FlowSpec::new(500_000).over(&[r2])));
        }
        for f in others {
            net.remove_flow(3.0, f);
        }
        assert_eq!(net.rate(a), rate_a, "a's rate must be untouched");
        assert_eq!(net.rate(b), rate_b, "b's rate must be untouched");
        assert_eq!(net.next_completion(3.0).unwrap(), (t0, id0));
    }

    #[test]
    fn capacity_change_reapplies_fair_shares() {
        // set_capacity applies from the last advance point, exactly as
        // the old deferred recompute did.
        let mut net = FlowNetwork::new();
        let r = net.add_resource(8e6);
        let a = net.start(0.0, FlowSpec::new(1_000_000).over(&[r]));
        let b = net.start(0.0, FlowSpec::new(1_000_000).over(&[r]));
        assert!((net.rate(a) - 4e6).abs() < EPS);
        net.set_capacity(r, 16e6);
        assert!((net.rate(a) - 8e6).abs() < EPS, "a={}", net.rate(a));
        assert!((net.rate(b) - 8e6).abs() < EPS);
        let (t, _) = net.next_completion(0.0).unwrap();
        assert!((t - 1.0).abs() < EPS, "t={t}");
    }

    #[test]
    fn pooled_vectors_are_transparent() {
        // Flows started after churn reuse recycled resource/position
        // vectors; the pooled path must produce identical rates and
        // completions to a fresh-pool start of the same specs.
        let mut net = FlowNetwork::new();
        let r0 = net.add_resource(10e6);
        let r1 = net.add_resource(4e6);
        let mk = |net: &mut FlowNetwork| {
            let a = net.start(0.0, FlowSpec::new(1_000_000).over(&[r0]));
            let b = net.start(0.0, FlowSpec::new(1_000_000).weight(0.5).over(&[r0, r1]));
            (a, b)
        };
        let (a, b) = mk(&mut net);
        let fresh = (net.rate(a), net.rate(b), net.next_completion(0.0).unwrap().0);
        net.remove_flow(0.0, a);
        net.remove_flow(0.0, b);
        let (a2, b2) = mk(&mut net);
        let reused = (net.rate(a2), net.rate(b2), net.next_completion(0.0).unwrap().0);
        assert_eq!(fresh, reused);
    }

    #[test]
    fn member_lists_survive_heavy_churn() {
        // Randomized interleaved start/remove keeps the swap-removed
        // member lists, back-pointers, and rates consistent (the debug
        // cross-check verifies rates against a full recompute here).
        use crate::util::rng::Rng;
        let mut rng = Rng::new(2008);
        let mut net = FlowNetwork::new();
        let rs: Vec<ResourceId> = (0..6).map(|_| net.add_resource(1e8)).collect();
        let mut live: Vec<FlowId> = Vec::new();
        let mut now = 0.0;
        for step in 0..400 {
            now += 0.001;
            if !live.is_empty() && (step % 3 == 0 || live.len() > 40) {
                let f = live.swap_remove(rng.index(live.len()));
                net.remove_flow(now, f);
            } else {
                let mut set = Vec::new();
                for _ in 0..rng.range_u64(1, 4) {
                    let r = rs[rng.index(rs.len())];
                    if !set.contains(&r) {
                        set.push(r);
                    }
                }
                live.push(net.start(now, FlowSpec::new(1_000_000).over(&set)));
            }
        }
        assert_eq!(net.active_flows(), live.len());
        for &f in &live {
            assert!(net.rate(f) > 0.0, "live flow {f:?} must make progress");
        }
        for f in live {
            net.remove_flow(now + 1.0, f);
        }
        assert_eq!(net.active_flows(), 0);
        for &r in &rs {
            assert_eq!(net.utilization(r), 0.0);
        }
    }
}
