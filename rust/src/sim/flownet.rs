//! Fluid flow network with weighted max-min fair sharing.
//!
//! Models every byte movement in the simulated system. A **resource** is a
//! capacity in bits/sec (GPFS aggregate read pool, a node's NIC-in, a
//! node's disk, ...). A **flow** is a transfer of `bytes` across a *set*
//! of resources; its instantaneous rate is bound by all of them.
//!
//! Rates follow **weighted max-min fairness** computed by progressive
//! filling: repeatedly find the bottleneck resource (smallest fair share
//! per unit weight), freeze the rates of the flows it carries at
//! `weight × share`, remove them, repeat. This is the standard fluid
//! approximation for TCP-like (or WFQ-shaped) sharing and is what makes
//! GPFS saturate at its aggregate cap while local-disk flows scale
//! linearly (each node's disk is a private resource).
//!
//! Weights are how the metered transfer plane ([`crate::transfer`])
//! bounds *in-flight* interference, not just admission: a background
//! staging flow started with weight 0.25 concedes 4/5 of a contended
//! link to a unit-weight foreground fetch, yet still runs — and the
//! allocation is **work-conserving**: share a bottlenecked flow cannot
//! use (because another resource binds it first) is redistributed to the
//! remaining flows, so capacity never idles while demand exists. With
//! every weight at 1.0 (the default — [`FlowNetwork::start_flow`]) the
//! arithmetic reduces bit-for-bit to the classic unweighted fair share.
//!
//! The driver couples this to the DES by asking for the next completion
//! time after every membership change and re-scheduling its completion
//! event (with a version counter to invalidate stale events).
//!
//! Storage is a **slab** (`Vec<Option<Flow>>` + free list): flow churn is
//! the hottest operation in big simulations and profiling showed hash
//! lookups inside the rate recomputation dominating wall time. Slab
//! indexing is branch-cheap and the iteration order is deterministic.

/// Identifies a capacity resource.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResourceId(pub u32);

/// Identifies an active flow: `(generation << 32) | slot`. Generations
/// make stale ids detectable after slot reuse.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FlowId(pub u64);

impl FlowId {
    #[inline]
    fn slot(self) -> usize {
        (self.0 & 0xFFFF_FFFF) as usize
    }
}

#[derive(Debug, Clone)]
struct Resource {
    capacity_bps: f64,
}

#[derive(Debug, Clone)]
struct Flow {
    id: FlowId,
    resources: Vec<ResourceId>,
    remaining_bits: f64,
    rate_bps: f64,
    /// Fair-share weight (1.0 = classic max-min; the transfer plane's
    /// background classes run below 1.0).
    weight: f64,
}

/// The flow network. Time is advanced explicitly by the caller.
#[derive(Debug, Default)]
pub struct FlowNetwork {
    resources: Vec<Resource>,
    slots: Vec<Option<Flow>>,
    free: Vec<u32>,
    active: usize,
    next_gen: u32,
    last_advance: f64,
    rates_dirty: bool,
    // Scratch buffers reused across recomputes.
    scratch_cap: Vec<f64>,
    scratch_wsum: Vec<f64>,
    scratch_unfixed: Vec<u32>,
    scratch_loaded: Vec<u32>,
}

/// A resource's weight-sum below this is treated as unloaded: exact for
/// unit weights (integral f64 subtraction leaves exactly 0.0) and absorbs
/// the last-ulp residue fractional weights can leave behind.
const WSUM_EPS: f64 = 1e-12;

impl FlowNetwork {
    /// Empty network.
    pub fn new() -> Self {
        FlowNetwork::default()
    }

    /// Register a resource with the given capacity (bits/sec).
    pub fn add_resource(&mut self, capacity_bps: f64) -> ResourceId {
        assert!(capacity_bps > 0.0, "resource capacity must be positive");
        self.resources.push(Resource { capacity_bps });
        ResourceId((self.resources.len() - 1) as u32)
    }

    /// Change a resource's capacity (e.g. provisioned bandwidth changes).
    pub fn set_capacity(&mut self, r: ResourceId, capacity_bps: f64) {
        self.resources[r.0 as usize].capacity_bps = capacity_bps;
        self.rates_dirty = true;
    }

    /// Start a unit-weight flow of `bytes` across `resources` at time
    /// `now`. A flow must cross at least one resource.
    pub fn start_flow(&mut self, now: f64, resources: Vec<ResourceId>, bytes: u64) -> FlowId {
        self.start_flow_weighted(now, resources, bytes, 1.0)
    }

    /// Start a flow carrying a fair-share `weight`: on every contended
    /// resource it receives capacity in proportion to its weight among
    /// the contending flows (clamped to a positive floor — a zero or
    /// negative weight would starve the flow forever and stall the DES).
    pub fn start_flow_weighted(
        &mut self,
        now: f64,
        resources: Vec<ResourceId>,
        bytes: u64,
        weight: f64,
    ) -> FlowId {
        assert!(!resources.is_empty(), "flow needs at least one resource");
        let weight = if weight.is_finite() { weight.max(1e-6) } else { 1.0 };
        self.advance_to(now);
        self.next_gen = self.next_gen.wrapping_add(1);
        let slot = match self.free.pop() {
            Some(s) => s as usize,
            None => {
                self.slots.push(None);
                self.slots.len() - 1
            }
        };
        let id = FlowId(((self.next_gen as u64) << 32) | slot as u64);
        self.slots[slot] = Some(Flow {
            id,
            resources,
            // A zero-byte flow (1-byte files exist in the paper's sweeps
            // once metadata dominates) still completes immediately; keep a
            // floor of one bit to avoid NaN rates.
            remaining_bits: (bytes as f64 * 8.0).max(1e-9),
            rate_bps: 0.0,
            weight,
        });
        self.active += 1;
        self.rates_dirty = true;
        id
    }

    #[inline]
    fn get(&self, id: FlowId) -> Option<&Flow> {
        match self.slots.get(id.slot()) {
            Some(Some(f)) if f.id == id => Some(f),
            _ => None,
        }
    }

    /// Progress all flows to time `now` at their current fair rates.
    pub fn advance_to(&mut self, now: f64) {
        if self.rates_dirty {
            self.recompute_rates();
        }
        let dt = now - self.last_advance;
        if dt > 0.0 {
            for flow in self.slots.iter_mut().flatten() {
                flow.remaining_bits = (flow.remaining_bits - flow.rate_bps * dt).max(0.0);
            }
        }
        if now > self.last_advance {
            self.last_advance = now;
        }
    }

    /// The earliest (time, flow) completion given current rates, or None
    /// if no flows are active. Call after `advance_to(now)`.
    pub fn next_completion(&mut self, now: f64) -> Option<(f64, FlowId)> {
        if self.rates_dirty {
            self.recompute_rates();
        }
        let mut best: Option<(f64, FlowId)> = None;
        for flow in self.slots.iter().flatten() {
            if flow.rate_bps <= 0.0 {
                continue;
            }
            let t = now + flow.remaining_bits / flow.rate_bps;
            match best {
                // Tie-break on FlowId for determinism.
                Some((bt, bid)) if t > bt || (t == bt && flow.id.0 > bid.0) => {}
                _ => best = Some((t, flow.id)),
            }
        }
        best
    }

    /// Remove a completed (or cancelled) flow. Returns remaining bytes
    /// (0 for a clean completion).
    pub fn remove_flow(&mut self, now: f64, id: FlowId) -> f64 {
        self.advance_to(now);
        let slot = id.slot();
        let flow = match self.slots.get_mut(slot) {
            Some(opt @ Some(_)) if opt.as_ref().unwrap().id == id => opt.take().unwrap(),
            _ => panic!("unknown flow {id:?}"),
        };
        self.free.push(slot as u32);
        self.active -= 1;
        self.rates_dirty = true;
        flow.remaining_bits / 8.0
    }

    /// Instantaneous utilization of a resource in [0, 1]: the sum of the
    /// fair-share rates of every flow crossing it over its capacity. The
    /// transfer plane's admission controller reads this to decide whether
    /// a source executor's egress can absorb background staging.
    pub fn utilization(&mut self, r: ResourceId) -> f64 {
        if self.rates_dirty {
            self.recompute_rates();
        }
        let cap = self.resources[r.0 as usize].capacity_bps;
        let mut used = 0.0;
        for flow in self.slots.iter().flatten() {
            if flow.resources.contains(&r) {
                used += flow.rate_bps;
            }
        }
        (used / cap).clamp(0.0, 1.0)
    }

    /// Instantaneous rate of a flow (bits/sec), for metrics.
    pub fn rate(&mut self, id: FlowId) -> f64 {
        if self.rates_dirty {
            self.recompute_rates();
        }
        self.get(id).map(|f| f.rate_bps).unwrap_or(0.0)
    }

    /// Resource set of a flow (testing / introspection).
    pub fn flow_resources(&self, id: FlowId) -> &[ResourceId] {
        self.get(id).map(|f| f.resources.as_slice()).unwrap_or(&[])
    }

    /// Fair-share weight of a flow (0.0 for a stale id).
    pub fn flow_weight(&self, id: FlowId) -> f64 {
        self.get(id).map(|f| f.weight).unwrap_or(0.0)
    }

    /// Capacity of a resource (testing / introspection).
    pub fn capacity(&self, r: ResourceId) -> f64 {
        self.resources[r.0 as usize].capacity_bps
    }

    /// Number of active flows.
    pub fn active_flows(&self) -> usize {
        self.active
    }

    /// Weighted max-min fair rates by progressive filling.
    ///
    /// Each resource tracks the *weight sum* of its unfixed flows; the
    /// per-level bottleneck share is `capacity / weight_sum` (share per
    /// unit weight, the WFQ virtual-time rate) and a frozen flow gets
    /// `weight × share`. Freezing subtracts the flow's granted rate from
    /// every resource it crosses, so share it cannot use elsewhere is
    /// redistributed to the survivors — work-conserving by construction.
    /// With all weights at 1.0 the weight sums are exact integers and the
    /// arithmetic is bit-identical to the classic unweighted filling.
    ///
    /// O(levels · (R + F)) over slab scans — no hashing, no allocation
    /// (scratch buffers are reused), no sort (slab order is already
    /// deterministic).
    fn recompute_rates(&mut self) {
        self.rates_dirty = false;
        let nr = self.resources.len();
        self.scratch_cap.clear();
        self.scratch_cap
            .extend(self.resources.iter().map(|r| r.capacity_bps));
        self.scratch_wsum.clear();
        self.scratch_wsum.resize(nr, 0.0);
        self.scratch_unfixed.clear();
        for (slot, flow) in self.slots.iter().enumerate() {
            if let Some(flow) = flow {
                self.scratch_unfixed.push(slot as u32);
                for r in &flow.resources {
                    self.scratch_wsum[r.0 as usize] += flow.weight;
                }
            }
        }
        let cap = &mut self.scratch_cap;
        let wsum = &mut self.scratch_wsum;
        // Only resources actually carrying flows participate; scanning the
        // full resource vector per level is wasted work on big testbeds
        // (4 resources per node × 64 nodes, few of them loaded at once).
        self.scratch_loaded.clear();
        for i in 0..nr {
            if wsum[i] > WSUM_EPS {
                self.scratch_loaded.push(i as u32);
            }
        }
        let mut n_unfixed = self.scratch_unfixed.len();
        while n_unfixed > 0 {
            // Bottleneck: min per-unit-weight share among loaded resources.
            let mut share = f64::INFINITY;
            let mut keep_loaded = 0usize;
            for k in 0..self.scratch_loaded.len() {
                let i = self.scratch_loaded[k] as usize;
                if wsum[i] > WSUM_EPS {
                    self.scratch_loaded[keep_loaded] = i as u32;
                    keep_loaded += 1;
                    let s = cap[i] / wsum[i];
                    if s < share {
                        share = s;
                    }
                }
            }
            self.scratch_loaded.truncate(keep_loaded);
            if !share.is_finite() {
                for &slot in &self.scratch_unfixed[..n_unfixed] {
                    self.slots[slot as usize].as_mut().unwrap().rate_bps = 0.0;
                }
                break;
            }
            // Freeze flows crossing a bottleneck resource at
            // `weight × share`, compacting survivors to the front.
            let mut keep = 0usize;
            for k in 0..n_unfixed {
                let slot = self.scratch_unfixed[k] as usize;
                let flow = self.slots[slot].as_mut().unwrap();
                let bottlenecked = flow.resources.iter().any(|r| {
                    let i = r.0 as usize;
                    wsum[i] > WSUM_EPS && (cap[i] / wsum[i]) <= share + 1e-9
                });
                if bottlenecked {
                    flow.rate_bps = flow.weight * share;
                    for r in &flow.resources {
                        let i = r.0 as usize;
                        cap[i] -= flow.weight * share;
                        wsum[i] -= flow.weight;
                    }
                } else {
                    self.scratch_unfixed[keep] = slot as u32;
                    keep += 1;
                }
            }
            debug_assert!(keep < n_unfixed, "progressive filling must shrink");
            n_unfixed = keep;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EPS: f64 = 1e-6;

    #[test]
    fn single_flow_gets_full_capacity() {
        let mut net = FlowNetwork::new();
        let r = net.add_resource(8e6); // 1 MB/s
        let f = net.start_flow(0.0, vec![r], 1_000_000);
        let (t, id) = net.next_completion(0.0).unwrap();
        assert_eq!(id, f);
        assert!((t - 1.0).abs() < EPS, "t={t}");
    }

    #[test]
    fn two_flows_share_fairly() {
        let mut net = FlowNetwork::new();
        let r = net.add_resource(8e6);
        let _a = net.start_flow(0.0, vec![r], 1_000_000);
        let _b = net.start_flow(0.0, vec![r], 1_000_000);
        // Each gets half: 2 s for both.
        let (t, _) = net.next_completion(0.0).unwrap();
        assert!((t - 2.0).abs() < EPS, "t={t}");
    }

    #[test]
    fn flow_bound_by_tightest_resource() {
        let mut net = FlowNetwork::new();
        let wide = net.add_resource(80e6);
        let narrow = net.add_resource(8e6);
        let f = net.start_flow(0.0, vec![wide, narrow], 1_000_000);
        assert!((net.rate(f) - 8e6).abs() < EPS);
    }

    #[test]
    fn max_min_textbook_example() {
        // Two resources: R0 cap 10, R1 cap 4 (bits/s).
        // Flow A uses {R0}, flow B uses {R0, R1}, flow C uses {R1}.
        // Progressive filling: R1 share = 2 -> B=C=2; then A gets 10-2=8.
        let mut net = FlowNetwork::new();
        let r0 = net.add_resource(10.0);
        let r1 = net.add_resource(4.0);
        let a = net.start_flow(0.0, vec![r0], 1000);
        let b = net.start_flow(0.0, vec![r0, r1], 1000);
        let c = net.start_flow(0.0, vec![r1], 1000);
        assert!((net.rate(a) - 8.0).abs() < EPS, "a={}", net.rate(a));
        assert!((net.rate(b) - 2.0).abs() < EPS, "b={}", net.rate(b));
        assert!((net.rate(c) - 2.0).abs() < EPS, "c={}", net.rate(c));
    }

    #[test]
    fn conservation_no_resource_oversubscribed() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(17);
        let mut net = FlowNetwork::new();
        let rs: Vec<ResourceId> = (0..10)
            .map(|_| net.add_resource(rng.range_f64(1e6, 1e9)))
            .collect();
        let mut flows = Vec::new();
        for _ in 0..100 {
            let k = rng.range_u64(1, 3) as usize;
            let mut set: Vec<ResourceId> = Vec::new();
            for _ in 0..k {
                let r = rs[rng.index(rs.len())];
                if !set.contains(&r) {
                    set.push(r);
                }
            }
            flows.push(net.start_flow(0.0, set, rng.range_u64(1, 1_000_000)));
        }
        // Sum of rates per resource must not exceed its capacity.
        let mut usage = vec![0.0f64; 10];
        for &f in &flows {
            let rate = net.rate(f);
            assert!(rate > 0.0, "every flow must make progress");
            for r in net.flow_resources(f).to_vec() {
                usage[r.0 as usize] += rate;
            }
        }
        for (i, u) in usage.iter().enumerate() {
            let cap = net.capacity(ResourceId(i as u32));
            assert!(*u <= cap * (1.0 + 1e-6), "resource {i}: {u} > {cap}");
        }
    }

    #[test]
    fn completion_matches_analytic_two_phase() {
        // Flow A (2 MB) and B (1 MB) share 8 Mb/s. B finishes at t=2
        // (rate 4 Mb/s → 8 Mbit / 4 Mbps). A then speeds up: it has
        // 8 Mbit left at t=2, finishing at t=3.
        let mut net = FlowNetwork::new();
        let r = net.add_resource(8e6);
        let a = net.start_flow(0.0, vec![r], 2_000_000);
        let b = net.start_flow(0.0, vec![r], 1_000_000);
        let (t1, id1) = net.next_completion(0.0).unwrap();
        assert_eq!(id1, b);
        assert!((t1 - 2.0).abs() < EPS);
        let left = net.remove_flow(t1, b);
        assert!(left.abs() < 1e-3);
        let (t2, id2) = net.next_completion(t1).unwrap();
        assert_eq!(id2, a);
        assert!((t2 - 3.0).abs() < EPS, "t2={t2}");
    }

    #[test]
    fn local_disks_scale_linearly_gpfs_saturates() {
        // The paper's core observation as a unit test: n private disk
        // resources aggregate n×, a shared pool stays flat.
        for n in [8usize, 16, 64] {
            let mut net = FlowNetwork::new();
            let gpfs = net.add_resource(3.4e9);
            let mut disk_flows = Vec::new();
            let mut gpfs_flows = Vec::new();
            for _ in 0..n {
                let disk = net.add_resource(470e6);
                disk_flows.push(net.start_flow(0.0, vec![disk], 100_000_000));
                gpfs_flows.push(net.start_flow(0.0, vec![gpfs], 100_000_000));
            }
            let disk_agg: f64 = disk_flows.iter().map(|&f| net.rate(f)).sum();
            let gpfs_agg: f64 = gpfs_flows.iter().map(|&f| net.rate(f)).sum();
            assert!((disk_agg - n as f64 * 470e6).abs() < 1.0);
            assert!((gpfs_agg - 3.4e9).abs() < 1.0);
        }
    }

    #[test]
    fn utilization_tracks_fair_share_load() {
        let mut net = FlowNetwork::new();
        let wide = net.add_resource(10e6);
        let narrow = net.add_resource(4e6);
        assert_eq!(net.utilization(wide), 0.0);
        // One flow bound by the narrow resource: wide carries 4 of 10.
        let f = net.start_flow(0.0, vec![wide, narrow], 1_000_000);
        assert!((net.utilization(narrow) - 1.0).abs() < EPS);
        assert!((net.utilization(wide) - 0.4).abs() < EPS);
        net.remove_flow(0.0, f);
        assert_eq!(net.utilization(narrow), 0.0);
    }

    #[test]
    fn weighted_flows_split_in_weight_proportion() {
        // Foreground (1.0) vs staging (0.25) on one 10 Mb/s link:
        // 8 Mb/s vs 2 Mb/s.
        let mut net = FlowNetwork::new();
        let r = net.add_resource(10e6);
        let fg = net.start_flow_weighted(0.0, vec![r], 1_000_000, 1.0);
        let bg = net.start_flow_weighted(0.0, vec![r], 1_000_000, 0.25);
        assert!((net.rate(fg) - 8e6).abs() < EPS, "fg={}", net.rate(fg));
        assert!((net.rate(bg) - 2e6).abs() < EPS, "bg={}", net.rate(bg));
        assert_eq!(net.flow_weight(fg), 1.0);
        assert_eq!(net.flow_weight(bg), 0.25);
        // Completion times follow the weighted rates: bg (2 Mb/s over
        // 8 Mbit) would finish at t=4; fg at t=1, after which bg speeds
        // up to the full link. fg completes first.
        let (t, id) = net.next_completion(0.0).unwrap();
        assert_eq!(id, fg);
        assert!((t - 1.0).abs() < EPS, "t={t}");
    }

    #[test]
    fn weighted_sharing_is_work_conserving() {
        // A low-weight flow alone still gets the whole link (weights
        // scale shares among *contenders*, they are not absolute caps).
        let mut net = FlowNetwork::new();
        let r = net.add_resource(10e6);
        let bg = net.start_flow_weighted(0.0, vec![r], 1_000_000, 0.1);
        assert!((net.rate(bg) - 10e6).abs() < EPS, "bg={}", net.rate(bg));
        // And share a bottlenecked-elsewhere flow cannot use is
        // redistributed: B (weight 1) is pinned to 1 Mb/s by a narrow
        // private link, so A (weight 0.25) takes the remaining 9 Mb/s.
        let mut net = FlowNetwork::new();
        let wide = net.add_resource(10e6);
        let narrow = net.add_resource(1e6);
        let a = net.start_flow_weighted(0.0, vec![wide], 1_000_000, 0.25);
        let b = net.start_flow_weighted(0.0, vec![wide, narrow], 1_000_000, 1.0);
        assert!((net.rate(b) - 1e6).abs() < EPS, "b={}", net.rate(b));
        assert!((net.rate(a) - 9e6).abs() < EPS, "a={}", net.rate(a));
    }

    #[test]
    fn unit_weights_match_unweighted_filling_exactly() {
        // start_flow and start_flow_weighted(…, 1.0) must be the same
        // computation bit-for-bit (the binary share policy relies on it).
        let build = |weighted: bool| {
            let mut net = FlowNetwork::new();
            let r0 = net.add_resource(10.0);
            let r1 = net.add_resource(4.0);
            let mk = |net: &mut FlowNetwork, rs: Vec<ResourceId>| {
                if weighted {
                    net.start_flow_weighted(0.0, rs, 1000, 1.0)
                } else {
                    net.start_flow(0.0, rs, 1000)
                }
            };
            let a = mk(&mut net, vec![r0]);
            let b = mk(&mut net, vec![r0, r1]);
            let c = mk(&mut net, vec![r1]);
            let rates = (net.rate(a), net.rate(b), net.rate(c));
            let next = net.next_completion(0.0).unwrap();
            (rates, next)
        };
        assert_eq!(build(false), build(true));
    }

    #[test]
    fn nonpositive_weight_is_clamped_not_starved() {
        let mut net = FlowNetwork::new();
        let r = net.add_resource(1e6);
        let f = net.start_flow_weighted(0.0, vec![r], 1_000, 0.0);
        assert!(net.rate(f) > 0.0, "clamped weight must still progress");
        let (t, _) = net.next_completion(0.0).unwrap();
        assert!(t.is_finite());
    }

    #[test]
    fn zero_byte_flow_completes() {
        let mut net = FlowNetwork::new();
        let r = net.add_resource(1e6);
        let _f = net.start_flow(0.0, vec![r], 0);
        let (t, _) = net.next_completion(0.0).unwrap();
        assert!(t < 1e-9);
    }

    #[test]
    fn slot_reuse_keeps_ids_distinct() {
        let mut net = FlowNetwork::new();
        let r = net.add_resource(1e6);
        let a = net.start_flow(0.0, vec![r], 100);
        net.remove_flow(0.0, a);
        let b = net.start_flow(0.0, vec![r], 100);
        assert_ne!(a, b, "generation must differ after slot reuse");
        assert_eq!(net.rate(a), 0.0, "stale id must read as inactive");
        assert!(net.rate(b) > 0.0);
        assert_eq!(net.active_flows(), 1);
    }
}
