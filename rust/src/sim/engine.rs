//! Minimal discrete-event engine with a calendar (bucketed) event queue.
//!
//! Events are user-defined values dispatched in time order to a `World`.
//! Determinism: ties in time are broken by insertion sequence, so a given
//! (config, seed) always replays identically.
//!
//! ## The calendar queue
//!
//! Extreme-scale runs (10⁵ executors, 10⁷–10⁸ events) spend real time in
//! the event queue, and a binary heap's `O(log n)` per operation with
//! cache-hostile sift paths shows up at the top of profiles. The queue
//! here is a classic *calendar queue* (Brown 1988): a ring of
//! [`NUM_BUCKETS`] buckets, each covering a `width`-second window of
//! simulated time. An event lands in the bucket of its time window —
//! `O(1)` — and the pop cursor sweeps the ring in time order, sorting a
//! bucket once on entry and draining it from the back. With bucket
//! occupancy near constant, insert and pop are `O(1)` amortized.
//!
//! * **Far-future fallback**: events beyond the ring's horizon
//!   (`NUM_BUCKETS × width` ahead) go to an overflow binary heap and
//!   migrate into their bucket when the cursor reaches their window, so a
//!   handful of long timers cannot force a huge bucket width.
//! * **Width adaptation**: the bucket width tracks an EWMA of observed
//!   inter-pop gaps, but is only re-anchored when every bucket is empty
//!   (the overflow heap is the sole survivor) — re-bucketing live events
//!   is never needed, and the adaptation is a pure function of the popped
//!   sequence, so it is deterministic.
//! * **Exact replay order**: events with equal times always land in the
//!   same bucket (or both in overflow); buckets sort by `(time, seq)` and
//!   the overflow heap compares the same key, so the pop sequence is
//!   *identical* to the old binary heap's — tie-break by insertion `seq`
//!   preserved exactly.
//!
//! [`EventQueue::at`] rejects non-finite times: a NaN would corrupt any
//! ordered structure silently (comparisons all answer "equal"), so it
//! panics at the insertion site instead of corrupting replay order.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// The simulation world: owns all state and handles events.
pub trait World {
    /// Event payload type.
    type Event;

    /// Handle one event at simulation time `now` (seconds). New events may
    /// be scheduled through `queue`.
    fn handle(&mut self, now: f64, event: Self::Event, queue: &mut EventQueue<Self::Event>);
}

struct Entry<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse order: earliest (time, seq) compares greatest, so the
        // overflow max-heap pops earliest-first and an ascending sort
        // leaves the earliest entry at the back of a bucket. Times are
        // guaranteed finite by `EventQueue::at`, so `total_cmp` agrees
        // with the usual `<` everywhere it is used.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Ring size. 2048 buckets × the adaptive width keeps a few thousand
/// events in the calendar at typical densities; the rest wait in the
/// overflow heap.
const NUM_BUCKETS: usize = 2048;
/// Initial bucket width (seconds) before any gap statistics exist.
const DEFAULT_WIDTH: f64 = 1e-3;
const MIN_WIDTH: f64 = 1e-9;
const MAX_WIDTH: f64 = 1e9;
/// Virtual bucket indices stay far below `u64::MAX` so index arithmetic
/// can never overflow; times mapping beyond this go to the overflow heap.
const MAX_VBUCKET: f64 = 1e18;

/// Pending-event queue handed to `World::handle`.
pub struct EventQueue<E> {
    /// The calendar ring. Bucket `vbucket % NUM_BUCKETS` covers simulated
    /// time `[vbucket·width, (vbucket+1)·width)`.
    buckets: Vec<Vec<Entry<E>>>,
    /// Virtual index of the bucket the pop cursor is on. Buckets are
    /// mapped from *absolute* time (`⌊t/width⌋`), never from a drifting
    /// accumulated base, so the time→bucket function is exact and
    /// monotone for the lifetime of a width.
    vbucket: u64,
    /// Current bucket width in seconds (re-anchored only when the
    /// calendar is empty).
    width: f64,
    /// Events currently in the ring (the rest are in `overflow`).
    in_buckets: usize,
    /// Whether the cursor's bucket has been sorted for draining. Arrivals
    /// into a sorted bucket use binary insertion; arrivals into any other
    /// bucket are plain pushes.
    cur_sorted: bool,
    /// Far-future events, beyond the ring horizon.
    overflow: BinaryHeap<Entry<E>>,
    seq: u64,
    now: f64,
    /// Time of the most recent pop, for the inter-event gap EWMA.
    last_pop: f64,
    /// EWMA of positive inter-pop gaps (0.0 until the first gap). Drives
    /// width adaptation at re-anchor time; a pure function of the popped
    /// sequence, so replay-deterministic.
    gap_ewma: f64,
}

impl<E> EventQueue<E> {
    /// Empty queue at time 0. Public so tests and benchmarks can drive
    /// the queue without an [`Engine`].
    pub fn new() -> Self {
        EventQueue {
            buckets: (0..NUM_BUCKETS).map(|_| Vec::new()).collect(),
            vbucket: 0,
            width: DEFAULT_WIDTH,
            in_buckets: 0,
            cur_sorted: false,
            overflow: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
            last_pop: 0.0,
            gap_ewma: 0.0,
        }
    }

    /// Current simulation time (seconds).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `event` at absolute time `at` (clamped to now — events in
    /// the past would break causality; we treat them as "immediately").
    ///
    /// Panics on NaN or `+∞`: `-∞` clamps to now like any past time, but
    /// a NaN compares "equal" to everything and would silently corrupt
    /// the pop order, so it is rejected at the source.
    pub fn at(&mut self, at: f64, event: E) {
        let time = if at < self.now { self.now } else { at };
        assert!(time.is_finite(), "event scheduled at non-finite time {at}");
        self.seq += 1;
        let seq = self.seq;
        self.insert(Entry { time, seq, event });
    }

    /// Schedule `event` at absolute time `at` with a caller-supplied
    /// ordering key in place of the internal insertion sequence.
    ///
    /// The parallel engine ([`crate::sim::parallel`]) uses this to
    /// deliver inter-site messages: the key is derived from the sender
    /// (site id + per-sender counter), so the pop order at equal times
    /// is a pure function of message identity, independent of the
    /// delivery (thread-interleaving) order. Keys must be unique and
    /// must have bit 63 set: that keeps them disjoint from the
    /// auto-incremented sequence numbers of [`EventQueue::at`], and
    /// makes same-time keyed events sort *after* locally scheduled
    /// ones.
    pub fn at_keyed(&mut self, at: f64, key: u64, event: E) {
        debug_assert!(key >> 63 == 1, "keyed events must set bit 63");
        let time = if at < self.now { self.now } else { at };
        assert!(time.is_finite(), "event scheduled at non-finite time {at}");
        self.insert(Entry {
            time,
            seq: key,
            event,
        });
    }

    /// Schedule `event` after a relative delay (seconds).
    pub fn after(&mut self, delay: f64, event: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.at(self.now + delay, event);
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.in_buckets + self.overflow.len()
    }

    /// Whether no events are pending.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Time of the earliest pending event (settles the cursor; `&mut`).
    pub fn peek_time(&mut self) -> Option<f64> {
        if self.settle() {
            let slot = (self.vbucket % NUM_BUCKETS as u64) as usize;
            Some(self.buckets[slot].last().unwrap().time)
        } else {
            None
        }
    }

    /// Pop the earliest pending event, advancing `now` to its time.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        if !self.settle() {
            return None;
        }
        let slot = (self.vbucket % NUM_BUCKETS as u64) as usize;
        let e = self.buckets[slot].pop().unwrap();
        self.in_buckets -= 1;
        debug_assert!(e.time >= self.now, "time went backwards");
        let gap = e.time - self.last_pop;
        if gap > 0.0 {
            self.gap_ewma = if self.gap_ewma > 0.0 {
                self.gap_ewma + 0.125 * (gap - self.gap_ewma)
            } else {
                gap
            };
        }
        self.last_pop = e.time;
        self.now = e.time;
        Some((e.time, e.event))
    }

    /// Map a time to its virtual bucket, or None when it lies beyond the
    /// representable range for the current width (→ overflow heap).
    #[inline]
    fn vb_of(&self, t: f64) -> Option<u64> {
        let q = t / self.width;
        if q < MAX_VBUCKET {
            Some(q as u64)
        } else {
            None
        }
    }

    #[inline]
    fn insert(&mut self, e: Entry<E>) {
        if self.in_buckets == 0 && self.overflow.is_empty() {
            // Queue is empty: re-anchor the calendar at this event so the
            // cursor never has to walk dead buckets from an old epoch.
            self.rebase(e.time);
        }
        match self.vb_of(e.time) {
            Some(vb) if vb < self.vbucket + NUM_BUCKETS as u64 => {
                // Times at or before the cursor's window (possible right
                // after a re-anchor jumped ahead of `now`) drain first,
                // so they belong in the cursor's bucket.
                let vb = vb.max(self.vbucket);
                let slot = (vb % NUM_BUCKETS as u64) as usize;
                self.in_buckets += 1;
                let bucket = &mut self.buckets[slot];
                if vb == self.vbucket && self.cur_sorted {
                    // Arrival into the bucket currently being drained:
                    // keep it sorted (ascending by the reversed `Ord`,
                    // i.e. earliest last) so pops stay exact.
                    let pos = bucket.binary_search_by(|p| p.cmp(&e)).unwrap_err();
                    bucket.insert(pos, e);
                } else {
                    bucket.push(e);
                }
            }
            _ => self.overflow.push(e),
        }
    }

    /// Re-anchor the (empty) calendar at time `t`, adapting the bucket
    /// width to the recent inter-pop gap EWMA.
    fn rebase(&mut self, t: f64) {
        debug_assert_eq!(self.in_buckets, 0, "rebase with live buckets");
        if self.gap_ewma > 0.0 {
            // ~4 events per bucket at the observed density.
            self.width = (self.gap_ewma * 4.0).clamp(MIN_WIDTH, MAX_WIDTH);
        }
        // Keep virtual indices representable even for huge times.
        while t / self.width >= MAX_VBUCKET {
            self.width *= 2.0;
        }
        self.vbucket = (t / self.width) as u64;
        self.cur_sorted = false;
    }

    /// Migrate overflow events that are due within the cursor's current
    /// window into its bucket. Called on every bucket entry, so no
    /// overflow event can ever be left behind the cursor.
    fn pull_due(&mut self, slot: usize) {
        let end = (self.vbucket + 1) as f64 * self.width;
        while let Some(top) = self.overflow.peek() {
            if top.time >= end {
                break;
            }
            let e = self.overflow.pop().unwrap();
            self.buckets[slot].push(e);
            self.in_buckets += 1;
        }
    }

    /// Advance the cursor to the bucket holding the earliest event and
    /// leave that bucket sorted for draining. Returns false iff empty.
    fn settle(&mut self) -> bool {
        loop {
            if self.in_buckets == 0 {
                let Some(top) = self.overflow.peek() else {
                    return false;
                };
                // Only far-future events remain: jump straight to the
                // earliest one's window (the only point where the width
                // may change).
                let t = top.time;
                self.rebase(t);
            }
            let slot = (self.vbucket % NUM_BUCKETS as u64) as usize;
            if !self.cur_sorted {
                self.pull_due(slot);
                self.buckets[slot].sort_unstable();
                self.cur_sorted = true;
            }
            if !self.buckets[slot].is_empty() {
                return true;
            }
            self.vbucket += 1;
            self.cur_sorted = false;
        }
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue::new()
    }
}

/// The engine: drives a `World` until the queue drains (or a limit hits).
pub struct Engine<W: World> {
    /// The simulation world (public so drivers can inspect state after
    /// the run).
    pub world: W,
    queue: EventQueue<W::Event>,
    events_processed: u64,
}

impl<W: World> Engine<W> {
    /// Create an engine around `world`.
    pub fn new(world: W) -> Self {
        Engine {
            world,
            queue: EventQueue::new(),
            events_processed: 0,
        }
    }

    /// Seed an initial event at absolute time `at`.
    pub fn schedule(&mut self, at: f64, event: W::Event) {
        self.queue.at(at, event);
    }

    /// Current simulation time.
    pub fn now(&self) -> f64 {
        self.queue.now
    }

    /// Total events processed so far.
    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// Run until the event queue is empty. Returns the final time.
    pub fn run(&mut self) -> f64 {
        self.run_until(f64::INFINITY, u64::MAX)
    }

    /// Run until the queue empties, `t_max` is reached, or `max_events`
    /// have been processed — whichever comes first.
    pub fn run_until(&mut self, t_max: f64, max_events: u64) -> f64 {
        while let Some(t) = self.queue.peek_time() {
            if t > t_max || self.events_processed >= max_events {
                break;
            }
            let (time, event) = self.queue.pop().unwrap();
            self.events_processed += 1;
            self.world.handle(time, event, &mut self.queue);
        }
        self.queue.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Recorder {
        seen: Vec<(f64, u32)>,
    }

    impl World for Recorder {
        type Event = u32;
        fn handle(&mut self, now: f64, ev: u32, q: &mut EventQueue<u32>) {
            self.seen.push((now, ev));
            // Event 1 spawns a chain.
            if ev == 1 && now < 5.0 {
                q.after(1.0, 1);
            }
        }
    }

    #[test]
    fn events_fire_in_time_order() {
        let mut eng = Engine::new(Recorder { seen: vec![] });
        eng.schedule(3.0, 30);
        eng.schedule(1.0, 10);
        eng.schedule(2.0, 20);
        eng.run();
        let evs: Vec<u32> = eng.world.seen.iter().map(|&(_, e)| e).collect();
        assert_eq!(evs, vec![10, 20, 30]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut eng = Engine::new(Recorder { seen: vec![] });
        eng.schedule(1.0, 1_000);
        eng.schedule(1.0, 2_000);
        eng.schedule(1.0, 3_000);
        eng.run();
        let evs: Vec<u32> = eng.world.seen.iter().map(|&(_, e)| e).collect();
        assert_eq!(evs, vec![1_000, 2_000, 3_000]);
    }

    #[test]
    fn chained_events_advance_clock() {
        let mut eng = Engine::new(Recorder { seen: vec![] });
        eng.schedule(0.0, 1);
        let end = eng.run();
        // Chain: 0,1,2,3,4,5 then stops (5.0 is not < 5.0).
        assert_eq!(eng.world.seen.len(), 6);
        assert!((end - 5.0).abs() < 1e-12);
    }

    #[test]
    fn run_until_respects_budget() {
        let mut eng = Engine::new(Recorder { seen: vec![] });
        for i in 0..100 {
            // Offset values so none triggers the Recorder's spawn chain.
            eng.schedule(i as f64, i + 1000);
        }
        eng.run_until(f64::INFINITY, 10);
        assert_eq!(eng.world.seen.len(), 10);
        eng.run_until(49.5, u64::MAX);
        assert_eq!(eng.world.seen.len(), 50);
    }

    #[test]
    fn past_events_clamp_to_now() {
        struct P {
            ok: bool,
        }
        impl World for P {
            type Event = u8;
            fn handle(&mut self, now: f64, ev: u8, q: &mut EventQueue<u8>) {
                if ev == 0 {
                    q.at(now - 100.0, 1); // in the past -> clamped
                } else {
                    self.ok = now >= 10.0;
                }
            }
        }
        let mut eng = Engine::new(P { ok: false });
        eng.schedule(10.0, 0);
        eng.run();
        assert!(eng.world.ok);
    }

    #[test]
    #[should_panic(expected = "non-finite time")]
    fn nan_times_are_rejected() {
        let mut q: EventQueue<u8> = EventQueue::new();
        q.at(f64::NAN, 0);
    }

    #[test]
    #[should_panic(expected = "non-finite time")]
    fn positive_infinity_is_rejected() {
        let mut q: EventQueue<u8> = EventQueue::new();
        q.at(f64::INFINITY, 0);
    }

    #[test]
    fn negative_infinity_clamps_to_now() {
        let mut q: EventQueue<u8> = EventQueue::new();
        q.at(f64::NEG_INFINITY, 7);
        assert_eq!(q.pop(), Some((0.0, 7)));
    }

    #[test]
    fn same_time_arrivals_mid_drain_stay_fifo() {
        // Exercises the sorted-insert path: events arriving at the exact
        // time of the bucket currently being drained must still pop in
        // insertion order after everything already pending at that time.
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..100 {
            q.at(1.0, i);
        }
        let mut got = Vec::new();
        for _ in 0..50 {
            got.push(q.pop().unwrap().1);
        }
        for i in 100..150 {
            q.at(1.0, i);
        }
        while let Some((t, v)) = q.pop() {
            assert_eq!(t, 1.0);
            got.push(v);
        }
        assert_eq!(got, (0..150).collect::<Vec<u32>>());
    }

    #[test]
    fn keyed_events_sort_after_locals_and_by_key_at_equal_time() {
        let mut q: EventQueue<u32> = EventQueue::new();
        // Keyed (message) arrivals delivered out of key order...
        q.at_keyed(1.0, (1 << 63) | (2 << 48) | 1, 202);
        q.at_keyed(1.0, (1 << 63) | (1 << 48) | 2, 102);
        q.at_keyed(1.0, (1 << 63) | (1 << 48) | 1, 101);
        // ...and locally scheduled events at the same time.
        q.at(1.0, 1);
        q.at(1.0, 2);
        let mut got = Vec::new();
        while let Some((_, v)) = q.pop() {
            got.push(v);
        }
        // Locals first (auto seq < any bit-63 key), then keyed events by
        // (sender, counter) regardless of insertion order.
        assert_eq!(got, vec![1, 2, 101, 102, 202]);
    }

    #[test]
    fn keyed_events_stay_ordered_mid_drain() {
        // The sorted-insert path must accept keyed entries too.
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..10 {
            q.at(1.0, i);
        }
        for _ in 0..5 {
            q.pop().unwrap();
        }
        q.at_keyed(1.0, (1 << 63) | 7, 99);
        let mut got = Vec::new();
        while let Some((_, v)) = q.pop() {
            got.push(v);
        }
        assert_eq!(got, vec![5, 6, 7, 8, 9, 99]);
    }

    #[test]
    fn far_future_events_overflow_and_pop_in_order() {
        // Times spanning many horizons (and forcing a re-anchor once the
        // near-term buckets drain) still pop in exact time order.
        let mut q: EventQueue<u32> = EventQueue::new();
        let times = [0.0, 1e6, 0.5, 5e5, 1e-4, 2.0, 1e6, 3.0, 7.5e5, 1e-4];
        for (i, &t) in times.iter().enumerate() {
            q.at(t, i as u32);
        }
        assert_eq!(q.len(), times.len());
        let mut popped = Vec::new();
        while let Some((t, v)) = q.pop() {
            popped.push((t, v));
        }
        let mut expect: Vec<(f64, u32)> =
            times.iter().enumerate().map(|(i, &t)| (t, i as u32)).collect();
        expect.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        assert_eq!(popped, expect);
        assert!(q.is_empty());
    }
}
