//! Minimal discrete-event engine.
//!
//! Events are user-defined values dispatched in time order to a `World`.
//! Determinism: ties in time are broken by insertion sequence, so a given
//! (config, seed) always replays identically.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// The simulation world: owns all state and handles events.
pub trait World {
    /// Event payload type.
    type Event;

    /// Handle one event at simulation time `now` (seconds). New events may
    /// be scheduled through `queue`.
    fn handle(&mut self, now: f64, event: Self::Event, queue: &mut EventQueue<Self::Event>);
}

struct Entry<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse order: BinaryHeap is a max-heap, we need earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Pending-event queue handed to `World::handle`.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: f64,
}

impl<E> EventQueue<E> {
    fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
        }
    }

    /// Current simulation time (seconds).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `event` at absolute time `at` (clamped to now — events in
    /// the past would break causality; we treat them as "immediately").
    pub fn at(&mut self, at: f64, event: E) {
        let time = if at < self.now { self.now } else { at };
        self.seq += 1;
        self.heap.push(Entry {
            time,
            seq: self.seq,
            event,
        });
    }

    /// Schedule `event` after a relative delay (seconds).
    pub fn after(&mut self, delay: f64, event: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.at(self.now + delay, event);
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// The engine: drives a `World` until the queue drains (or a limit hits).
pub struct Engine<W: World> {
    /// The simulation world (public so drivers can inspect state after
    /// the run).
    pub world: W,
    queue: EventQueue<W::Event>,
    events_processed: u64,
}

impl<W: World> Engine<W> {
    /// Create an engine around `world`.
    pub fn new(world: W) -> Self {
        Engine {
            world,
            queue: EventQueue::new(),
            events_processed: 0,
        }
    }

    /// Seed an initial event at absolute time `at`.
    pub fn schedule(&mut self, at: f64, event: W::Event) {
        self.queue.at(at, event);
    }

    /// Current simulation time.
    pub fn now(&self) -> f64 {
        self.queue.now
    }

    /// Total events processed so far.
    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// Run until the event queue is empty. Returns the final time.
    pub fn run(&mut self) -> f64 {
        self.run_until(f64::INFINITY, u64::MAX)
    }

    /// Run until the queue empties, `t_max` is reached, or `max_events`
    /// have been processed — whichever comes first.
    pub fn run_until(&mut self, t_max: f64, max_events: u64) -> f64 {
        while let Some(top) = self.queue.heap.peek() {
            if top.time > t_max || self.events_processed >= max_events {
                break;
            }
            let entry = self.queue.heap.pop().unwrap();
            debug_assert!(entry.time >= self.queue.now, "time went backwards");
            self.queue.now = entry.time;
            self.events_processed += 1;
            self.world.handle(entry.time, entry.event, &mut self.queue);
        }
        self.queue.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Recorder {
        seen: Vec<(f64, u32)>,
    }

    impl World for Recorder {
        type Event = u32;
        fn handle(&mut self, now: f64, ev: u32, q: &mut EventQueue<u32>) {
            self.seen.push((now, ev));
            // Event 1 spawns a chain.
            if ev == 1 && now < 5.0 {
                q.after(1.0, 1);
            }
        }
    }

    #[test]
    fn events_fire_in_time_order() {
        let mut eng = Engine::new(Recorder { seen: vec![] });
        eng.schedule(3.0, 30);
        eng.schedule(1.0, 10);
        eng.schedule(2.0, 20);
        eng.run();
        let evs: Vec<u32> = eng.world.seen.iter().map(|&(_, e)| e).collect();
        assert_eq!(evs, vec![10, 20, 30]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut eng = Engine::new(Recorder { seen: vec![] });
        eng.schedule(1.0, 1_000);
        eng.schedule(1.0, 2_000);
        eng.schedule(1.0, 3_000);
        eng.run();
        let evs: Vec<u32> = eng.world.seen.iter().map(|&(_, e)| e).collect();
        assert_eq!(evs, vec![1_000, 2_000, 3_000]);
    }

    #[test]
    fn chained_events_advance_clock() {
        let mut eng = Engine::new(Recorder { seen: vec![] });
        eng.schedule(0.0, 1);
        let end = eng.run();
        // Chain: 0,1,2,3,4,5 then stops (5.0 is not < 5.0).
        assert_eq!(eng.world.seen.len(), 6);
        assert!((end - 5.0).abs() < 1e-12);
    }

    #[test]
    fn run_until_respects_budget() {
        let mut eng = Engine::new(Recorder { seen: vec![] });
        for i in 0..100 {
            // Offset values so none triggers the Recorder's spawn chain.
            eng.schedule(i as f64, i + 1000);
        }
        eng.run_until(f64::INFINITY, 10);
        assert_eq!(eng.world.seen.len(), 10);
        eng.run_until(49.5, u64::MAX);
        assert_eq!(eng.world.seen.len(), 50);
    }

    #[test]
    fn past_events_clamp_to_now() {
        struct P {
            ok: bool,
        }
        impl World for P {
            type Event = u8;
            fn handle(&mut self, now: f64, ev: u8, q: &mut EventQueue<u8>) {
                if ev == 0 {
                    q.at(now - 100.0, 1); // in the past -> clamped
                } else {
                    self.ok = now >= 10.0;
                }
            }
        }
        let mut eng = Engine::new(P { ok: false });
        eng.schedule(10.0, 0);
        eng.run();
        assert!(eng.world.ok);
    }
}
