//! Conservative-lookahead parallel discrete-event engine.
//!
//! The serial [`Engine`](super::Engine) drives one world with one queue;
//! multi-site federation runs decompose into per-site worlds whose only
//! coupling is WAN traffic, which physically takes at least the
//! site-pair latency floor to arrive. This module exploits that bound
//! with a classic *conservative* parallel-DES protocol, executed in
//! barrier-synchronized rounds:
//!
//! 1. **Deliver** — messages staged in the previous round are inserted
//!    into their destination queues via [`EventQueue::at_keyed`], with a
//!    sender-derived ordering key so the insertion (thread) order never
//!    affects pop order.
//! 2. **Window** — `T` is the global minimum next-event time across all
//!    sites. If no site has a pending event, the run is over.
//! 3. **Execute** — every site processes its events with `t < T + h(i)`
//!    in parallel, where the *lookahead* `h(i)` is the minimum WAN
//!    latency from any other site into `i` (from
//!    [`Topology::lookahead_in`](crate::federation::Topology::lookahead_in)).
//!    Any message emitted this round is sent at some `t ≥ T` and so
//!    arrives at `t + lat(j→i) ≥ T + h(i)` — strictly after every event
//!    executed at `i` this round. Emitted messages go to per-site
//!    outboxes ([`SiteWorld::drain_outbox`]) and are routed at the
//!    round barrier.
//!
//! If `h(i)` is zero (a zero-latency site pair, or `T + h(i)` rounds
//! down to `T`), site `i` degrades to processing `t ≤ T` only; same-time
//! message arrivals then execute in the next round, after same-time
//! local events — exactly where the keyed ordering would place them.
//! The site holding the global minimum always executes at least one
//! event, so every round makes progress.
//!
//! ## Serial-equivalence contract
//!
//! The round structure — delivery, `T`, per-site windows, message
//! routing — is a pure function of global simulation state; worker
//! threads only parallelize step 3 *across* sites, and each site's event
//! stream is handled by exactly one thread per round. `threads = 1` runs
//! the identical round loop inline. Run outcomes (event counts,
//! makespan, metric checksums) are therefore bit-for-bit identical at
//! every thread count, pinned by `tests/parallel_equivalence.rs`.

use std::sync::{Barrier, Mutex};

use super::engine::{EventQueue, World};

/// A world that can run as one site of a multi-site simulation.
///
/// Cross-site interactions must never touch another site's state
/// directly: they are expressed as timestamped messages staged in an
/// outbox while handling events, routed by the engine at round
/// barriers, and delivered to the destination as ordinary events.
pub trait SiteWorld: World + Send {
    /// Inter-site message payload.
    type Msg: Send;

    /// Drain the messages staged while handling events this round.
    fn drain_outbox(&mut self) -> Vec<OutMsg<Self::Msg>>;

    /// Wrap an arriving message (with its sender's site id) as a local
    /// event for [`World::handle`].
    fn msg_event(from: u32, msg: Self::Msg) -> Self::Event;
}

/// One staged inter-site message.
pub struct OutMsg<M> {
    /// Destination site index.
    pub dst: usize,
    /// Absolute arrival time (send time + site-pair latency).
    pub at: f64,
    /// Ordering key for [`EventQueue::at_keyed`]: unique, bit 63 set,
    /// derived from (sender site, per-sender counter) so equal-time
    /// delivery order is reproducible.
    pub key: u64,
    /// The payload.
    pub msg: M,
}

/// A message in flight between rounds (tagged with its sender).
struct InMsg<M> {
    at: f64,
    key: u64,
    from: u32,
    msg: M,
}

/// One site: its world, its event queue, and its event counter.
pub struct SiteState<W: SiteWorld> {
    /// The site-local world.
    pub world: W,
    /// The site-local event queue.
    pub queue: EventQueue<W::Event>,
    /// Events executed at this site.
    pub events: u64,
}

/// The parallel engine: a set of site worlds advanced in
/// conservative-lookahead rounds (see the module docs).
pub struct ParallelEngine<W: SiteWorld> {
    sites: Vec<SiteState<W>>,
    lookahead: Vec<f64>,
    threads: usize,
}

/// Execute one site's window `[.., limit)` (or `[.., T]` when the
/// lookahead collapsed) and return the messages it staged.
fn run_window<W: SiteWorld>(s: &mut SiteState<W>, t: f64, h: f64) -> Vec<OutMsg<W::Msg>> {
    let limit = t + h;
    // `h` may be 0, or small enough that `t + h` rounds back to `t`;
    // fall back to the inclusive window `t ≤ T` so the round still
    // makes progress.
    let inclusive = limit <= t;
    loop {
        match s.queue.peek_time() {
            Some(pt) if (inclusive && pt <= t) || (!inclusive && pt < limit) => {
                let (now, ev) = s.queue.pop().unwrap();
                s.events += 1;
                s.world.handle(now, ev, &mut s.queue);
            }
            _ => break,
        }
    }
    s.world.drain_outbox()
}

impl<W: SiteWorld> ParallelEngine<W>
where
    W::Event: Send,
{
    /// Empty engine that will use up to `threads` worker threads
    /// (clamped to the site count; `1` runs the round loop inline).
    pub fn new(threads: usize) -> Self {
        ParallelEngine {
            sites: Vec::new(),
            lookahead: Vec::new(),
            threads: threads.max(1),
        }
    }

    /// Add a site with its incoming lookahead `h` (seconds): the
    /// minimum latency with which any other site's message can reach
    /// it. `f64::INFINITY` is valid for a site nothing can send to
    /// (its window is then unbounded).
    pub fn add_site(&mut self, world: W, lookahead_in: f64) -> usize {
        debug_assert!(lookahead_in >= 0.0);
        self.sites.push(SiteState {
            world,
            queue: EventQueue::new(),
            events: 0,
        });
        self.lookahead.push(lookahead_in);
        self.sites.len() - 1
    }

    /// Seed an event at `site`'s queue at absolute time `at`.
    pub fn schedule(&mut self, site: usize, at: f64, event: W::Event) {
        self.sites[site].queue.at(at, event);
    }

    /// Total events executed across all sites.
    pub fn events_processed(&self) -> u64 {
        self.sites.iter().map(|s| s.events).sum()
    }

    /// The sites (worlds inspectable after the run).
    pub fn sites(&self) -> &[SiteState<W>] {
        &self.sites
    }

    /// Consume the engine, yielding the site states for harvesting.
    pub fn into_sites(self) -> Vec<SiteState<W>> {
        self.sites
    }

    /// Run until every queue drains and no messages are in flight.
    /// Returns the maximum site-local end time.
    pub fn run(&mut self) -> f64 {
        let k = self.threads.min(self.sites.len()).max(1);
        if k <= 1 {
            self.run_serial();
        } else {
            self.run_parallel(k);
        }
        self.sites.iter().map(|s| s.queue.now()).fold(0.0, f64::max)
    }

    /// The round loop, inline on the calling thread.
    fn run_serial(&mut self) {
        let n = self.sites.len();
        let mut pending: Vec<Vec<InMsg<W::Msg>>> = (0..n).map(|_| Vec::new()).collect();
        loop {
            let mut t = f64::INFINITY;
            for (i, s) in self.sites.iter_mut().enumerate() {
                for m in pending[i].drain(..) {
                    s.queue.at_keyed(m.at, m.key, W::msg_event(m.from, m.msg));
                }
                if let Some(pt) = s.queue.peek_time() {
                    t = t.min(pt);
                }
            }
            if !t.is_finite() {
                break;
            }
            for i in 0..n {
                for m in run_window(&mut self.sites[i], t, self.lookahead[i]) {
                    pending[m.dst].push(InMsg {
                        at: m.at,
                        key: m.key,
                        from: i as u32,
                        msg: m.msg,
                    });
                }
            }
        }
    }

    /// The identical round loop across `k` persistent scoped workers
    /// (sites assigned round-robin), synchronized with a barrier three
    /// times per round: after delivery/min-reporting, after the window
    /// reduction, and after outbox routing.
    fn run_parallel(&mut self, k: usize) {
        let n = self.sites.len();
        let lookahead = std::mem::take(&mut self.lookahead);
        let mut groups: Vec<Vec<(usize, SiteState<W>)>> = (0..k).map(|_| Vec::new()).collect();
        for (i, s) in std::mem::take(&mut self.sites).into_iter().enumerate() {
            groups[i % k].push((i, s));
        }

        struct Shared<M> {
            pending: Vec<Vec<InMsg<M>>>,
            mins: Vec<f64>,
            window: f64,
            done: bool,
        }
        let shared = Mutex::new(Shared {
            pending: (0..n).map(|_| Vec::new()).collect(),
            mins: vec![f64::INFINITY; k],
            window: 0.0,
            done: false,
        });
        let barrier = Barrier::new(k);

        let finished: Vec<Vec<(usize, SiteState<W>)>> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(k);
            for (w, mut group) in groups.into_iter().enumerate() {
                let shared = &shared;
                let barrier = &barrier;
                let lookahead = &lookahead;
                handles.push(scope.spawn(move || {
                    loop {
                        // Deliver staged messages to my sites, then
                        // report my local minimum next-event time.
                        {
                            let mut sh = shared.lock().unwrap();
                            let mut lmin = f64::INFINITY;
                            for (i, s) in group.iter_mut() {
                                for m in sh.pending[*i].drain(..) {
                                    s.queue.at_keyed(m.at, m.key, W::msg_event(m.from, m.msg));
                                }
                                if let Some(pt) = s.queue.peek_time() {
                                    lmin = lmin.min(pt);
                                }
                            }
                            sh.mins[w] = lmin;
                        }
                        barrier.wait();
                        // One worker reduces the global window (min is
                        // order-insensitive, so this is deterministic).
                        if w == 0 {
                            let mut sh = shared.lock().unwrap();
                            let t = sh.mins.iter().copied().fold(f64::INFINITY, f64::min);
                            sh.window = t;
                            sh.done = !t.is_finite();
                        }
                        barrier.wait();
                        let (t, done) = {
                            let sh = shared.lock().unwrap();
                            (sh.window, sh.done)
                        };
                        if done {
                            break;
                        }
                        // Execute my sites' windows; stage emitted
                        // messages for next round's delivery phase.
                        let mut staged: Vec<(usize, InMsg<W::Msg>)> = Vec::new();
                        for (i, s) in group.iter_mut() {
                            for m in run_window(s, t, lookahead[*i]) {
                                staged.push((
                                    m.dst,
                                    InMsg {
                                        at: m.at,
                                        key: m.key,
                                        from: *i as u32,
                                        msg: m.msg,
                                    },
                                ));
                            }
                        }
                        if !staged.is_empty() {
                            let mut sh = shared.lock().unwrap();
                            for (dst, m) in staged {
                                sh.pending[dst].push(m);
                            }
                        }
                        barrier.wait();
                    }
                    group
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("site worker panicked"))
                .collect()
        });

        let mut sites: Vec<Option<SiteState<W>>> = (0..n).map(|_| None).collect();
        for group in finished {
            for (i, s) in group {
                sites[i] = Some(s);
            }
        }
        self.sites = sites.into_iter().map(|s| s.unwrap()).collect();
        self.lookahead = lookahead;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy site: every handled event logs itself and forwards a message
    /// to the next site in the ring until the hop budget is spent.
    struct Ring {
        id: u32,
        n: u32,
        latency: f64,
        hops_left: u32,
        sent: u64,
        log: Vec<(f64, u32)>,
        outbox: Vec<OutMsg<u32>>,
    }

    enum TEv {
        Local(u32),
        Msg(u32),
    }

    impl World for Ring {
        type Event = TEv;
        fn handle(&mut self, now: f64, ev: TEv, _q: &mut EventQueue<TEv>) {
            let x = match ev {
                TEv::Local(x) | TEv::Msg(x) => x,
            };
            self.log.push((now, x));
            if self.hops_left > 0 {
                self.hops_left -= 1;
                self.sent += 1;
                self.outbox.push(OutMsg {
                    dst: ((self.id + 1) % self.n) as usize,
                    at: now + self.latency,
                    key: (1 << 63) | ((self.id as u64) << 48) | self.sent,
                    msg: x + 1,
                });
            }
        }
    }

    impl SiteWorld for Ring {
        type Msg = u32;
        fn drain_outbox(&mut self) -> Vec<OutMsg<u32>> {
            std::mem::take(&mut self.outbox)
        }
        fn msg_event(_from: u32, msg: u32) -> TEv {
            TEv::Msg(msg)
        }
    }

    fn run_ring(n: u32, latency: f64, threads: usize) -> (Vec<Vec<(f64, u32)>>, u64, f64) {
        let mut eng = ParallelEngine::new(threads);
        let h = if n > 1 { latency } else { f64::INFINITY };
        for id in 0..n {
            eng.add_site(
                Ring {
                    id,
                    n,
                    latency,
                    hops_left: 25,
                    sent: 0,
                    log: Vec::new(),
                    outbox: Vec::new(),
                },
                h,
            );
        }
        for i in 0..n as usize {
            eng.schedule(i, i as f64 * 0.01, TEv::Local(0));
        }
        let end = eng.run();
        let events = eng.events_processed();
        let logs = eng.into_sites().into_iter().map(|s| s.world.log).collect();
        (logs, events, end)
    }

    #[test]
    fn thread_counts_agree_bit_for_bit() {
        let serial = run_ring(4, 0.05, 1);
        for threads in [2, 4, 8] {
            let par = run_ring(4, 0.05, threads);
            assert_eq!(serial.0, par.0, "logs diverged at threads={threads}");
            assert_eq!(serial.1, par.1);
            assert_eq!(serial.2.to_bits(), par.2.to_bits());
        }
    }

    #[test]
    fn zero_lookahead_degrades_without_deadlock() {
        // Zero-latency messages force the inclusive `t ≤ T` window; the
        // run must still terminate and stay thread-count invariant.
        let serial = run_ring(3, 0.0, 1);
        let par = run_ring(3, 0.0, 3);
        assert_eq!(serial.0, par.0);
        assert_eq!(serial.1, par.1);
        assert!(serial.1 > 0);
    }

    #[test]
    fn single_site_drains_in_one_round() {
        let (logs, events, _) = run_ring(1, 1.0, 4);
        // 1 seed + 25 self-hops.
        assert_eq!(events, 26);
        assert_eq!(logs[0].len(), 26);
    }
}
