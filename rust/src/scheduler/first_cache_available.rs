//! `first-cache-available`: location-unaware executor choice, but the
//! dispatcher performs index lookups and ships location hints with the
//! task, so the executor can fetch from its own cache or a peer instead
//! of persistent storage.

use super::decision::{BatchScratch, Decision, SchedView};
use crate::coordinator::task::Task;

/// Decide per the first-cache-available policy.
pub fn decide(task: &Task, view: &SchedView) -> Decision {
    decide_with(task, view, &mut BatchScratch::default())
}

/// [`decide`] with a caller-owned scoring scratch (unused here: the
/// executor choice is location-unaware; hints come from the index
/// directly).
pub fn decide_with(task: &Task, view: &SchedView, _scratch: &mut BatchScratch) -> Decision {
    match view.idle.first() {
        Some(&executor) => Decision::Dispatch {
            executor,
            hints: view.hints_for(task),
        },
        None => Decision::NoExecutor,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Task, TaskId};
    use crate::index::central::CentralIndex;
    use crate::storage::object::{Catalog, ObjectId};

    #[test]
    fn ships_hints_but_keeps_fifo_choice() {
        let mut idx = CentralIndex::new();
        idx.insert(ObjectId(1), 5);
        let mut cat = Catalog::new();
        cat.insert(ObjectId(1), 10);
        let view = SchedView {
            idle: &[2, 5],
            all: &[2, 5],
            index: &idx,
            catalog: &cat,
        };
        let task = Task::with_inputs(TaskId(1), vec![ObjectId(1)]);
        match decide(&task, &view) {
            Decision::Dispatch { executor, hints } => {
                // Still the *first* idle executor, not the data-holder...
                assert_eq!(executor, 2);
                // ...but with the peer location attached.
                assert_eq!(hints.get(&ObjectId(1)), Some(&vec![5]));
            }
            other => panic!("unexpected: {other:?}"),
        }
    }
}
