//! Data-aware task dispatch (§3.2.2).
//!
//! Four policies, exactly as the paper defines them:
//!
//! * [`DispatchPolicy::FirstAvailable`] — ignore data location entirely;
//!   the executor gets no hints and must read everything from persistent
//!   storage.
//! * [`DispatchPolicy::FirstCacheAvailable`] — same executor choice, but
//!   the dispatcher looks up each needed object and ships location hints,
//!   so the executor can fetch from its own cache / a peer / GPFS.
//! * [`DispatchPolicy::MaxCacheHit`] — send the task to the executor with
//!   the most needed data **even if it is busy** (dispatch is delayed
//!   until it frees up) — maximal cache reuse, possible load imbalance.
//! * [`DispatchPolicy::MaxComputeUtil`] — among **available** executors,
//!   pick the one with the most needed bytes; never delays.
//!
//! The decision function is pure — it reads a [`SchedView`] and returns a
//! [`Decision`] — so it is shared verbatim by the simulated and live
//! drivers and is directly property-testable.

pub mod decision;
pub mod first_available;
pub mod first_cache_available;
pub mod max_cache_hit;
pub mod max_compute_util;
pub mod queue;

pub use decision::{BatchScratch, Decision, LocationHints, SchedView};
pub use queue::WaitQueue;

use crate::coordinator::task::Task;

/// Task dispatch policy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Location-unaware, no hints (configuration (3) in §4.3).
    FirstAvailable,
    /// Location-unaware choice with location hints (configuration (5)/(6)).
    FirstCacheAvailable,
    /// Most cached data wins, may delay behind a busy executor.
    MaxCacheHit,
    /// Most cached data among idle executors (configuration (7)/(8)).
    MaxComputeUtil,
}

impl DispatchPolicy {
    /// Parse from config/CLI text (paper naming, kebab-case).
    pub fn parse(s: &str) -> Option<DispatchPolicy> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "first-available" => Some(DispatchPolicy::FirstAvailable),
            "first-cache-available" => Some(DispatchPolicy::FirstCacheAvailable),
            "max-cache-hit" => Some(DispatchPolicy::MaxCacheHit),
            "max-compute-util" => Some(DispatchPolicy::MaxComputeUtil),
            _ => None,
        }
    }

    /// Display label (paper naming).
    pub fn label(&self) -> &'static str {
        match self {
            DispatchPolicy::FirstAvailable => "first-available",
            DispatchPolicy::FirstCacheAvailable => "first-cache-available",
            DispatchPolicy::MaxCacheHit => "max-cache-hit",
            DispatchPolicy::MaxComputeUtil => "max-compute-util",
        }
    }

    /// Whether this policy consults the central index at all.
    pub fn is_data_aware(&self) -> bool {
        !matches!(self, DispatchPolicy::FirstAvailable)
    }

    /// Make a dispatch decision for `task` given the current view.
    pub fn decide(&self, task: &Task, view: &SchedView) -> Decision {
        self.decide_with(task, view, &mut BatchScratch::default())
    }

    /// [`decide`] with a caller-owned [`BatchScratch`]: the batched
    /// dispatcher drains the ready queue once per wake-up and scores the
    /// whole batch through one reused accumulator instead of allocating
    /// per task. Decisions are identical to [`decide`] by construction.
    ///
    /// [`decide`]: DispatchPolicy::decide
    pub fn decide_with(
        &self,
        task: &Task,
        view: &SchedView,
        scratch: &mut BatchScratch,
    ) -> Decision {
        match self {
            DispatchPolicy::FirstAvailable => first_available::decide_with(task, view, scratch),
            DispatchPolicy::FirstCacheAvailable => {
                first_cache_available::decide_with(task, view, scratch)
            }
            DispatchPolicy::MaxCacheHit => max_cache_hit::decide_with(task, view, scratch),
            DispatchPolicy::MaxComputeUtil => max_compute_util::decide_with(task, view, scratch),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_paper_names() {
        assert_eq!(
            DispatchPolicy::parse("first-available"),
            Some(DispatchPolicy::FirstAvailable)
        );
        assert_eq!(
            DispatchPolicy::parse("max_compute_util"),
            Some(DispatchPolicy::MaxComputeUtil)
        );
        assert_eq!(DispatchPolicy::parse("round-robin"), None);
    }

    #[test]
    fn data_awareness_classification() {
        assert!(!DispatchPolicy::FirstAvailable.is_data_aware());
        assert!(DispatchPolicy::FirstCacheAvailable.is_data_aware());
        assert!(DispatchPolicy::MaxCacheHit.is_data_aware());
        assert!(DispatchPolicy::MaxComputeUtil.is_data_aware());
    }
}
