//! The dispatcher's wait queue, including max-cache-hit delayed tasks.
//!
//! Plain FIFO for incoming tasks, plus a parking area for tasks that
//! max-cache-hit chose to delay behind a specific busy executor. When
//! that executor reports back, its parked tasks re-enter consideration
//! ahead of the FIFO (they were admitted earlier).

use std::collections::VecDeque;

use crate::coordinator::task::Task;
use crate::index::central::ExecutorId;
use crate::util::fxhash::FxHashMap;

/// Wait queue with executor-parked delays.
#[derive(Debug, Default)]
pub struct WaitQueue {
    fifo: VecDeque<Task>,
    // FxHashMap like the rest of the dispatch hot path: park/release runs
    // on every max-cache-hit decision and executor report-back.
    parked: FxHashMap<ExecutorId, VecDeque<Task>>,
    parked_count: usize,
    peak: usize,
}

impl WaitQueue {
    /// Empty queue.
    pub fn new() -> Self {
        WaitQueue::default()
    }

    /// Enqueue a freshly submitted task.
    pub fn push(&mut self, task: Task) {
        self.fifo.push_back(task);
        self.peak = self.peak.max(self.len());
    }

    /// Put a task back at the *front* (a dispatch attempt found no
    /// executor; preserves FIFO order for the next attempt).
    pub fn push_front(&mut self, task: Task) {
        self.fifo.push_front(task);
    }

    /// Park a task waiting for a specific busy executor.
    pub fn park(&mut self, executor: ExecutorId, task: Task) {
        self.parked.entry(executor).or_default().push_back(task);
        self.parked_count += 1;
        self.peak = self.peak.max(self.len());
    }

    /// Executor became available: release its parked tasks (FIFO among
    /// themselves) to the front of the queue.
    pub fn release(&mut self, executor: ExecutorId) {
        if let Some(mut tasks) = self.parked.remove(&executor) {
            self.parked_count -= tasks.len();
            while let Some(t) = tasks.pop_back() {
                self.fifo.push_front(t);
            }
        }
    }

    /// Next task to consider for dispatch.
    pub fn pop(&mut self) -> Option<Task> {
        self.fifo.pop_front()
    }

    /// Iterate the ready (non-parked) tasks in FIFO order, for the
    /// data-aware matcher's window scan.
    pub fn iter_ready(&self) -> impl Iterator<Item = &Task> {
        self.fifo.iter()
    }

    /// Remove the ready task at FIFO position `pos` (0 = front).
    pub fn remove_ready_at(&mut self, pos: usize) -> Option<Task> {
        self.fifo.remove(pos)
    }

    /// Tasks waiting (FIFO + parked).
    pub fn len(&self) -> usize {
        self.fifo.len() + self.parked_count
    }

    /// Whether nothing is waiting anywhere.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Tasks immediately dispatchable (not parked).
    pub fn ready_len(&self) -> usize {
        self.fifo.len()
    }

    /// Steal up to `max` ready tasks from the *back* of the FIFO,
    /// returned in their original front-to-back order. The back is where
    /// the youngest work sits, so a thief takes the tasks that would have
    /// waited longest here while the victim keeps its oldest (closest to
    /// dispatch) tasks. Parked tasks are never stolen: they wait on a
    /// specific busy executor that only the owning shard tracks.
    pub fn steal_back(&mut self, max: usize) -> Vec<Task> {
        let n = max.min(self.fifo.len());
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            if let Some(t) = self.fifo.pop_back() {
                out.push(t);
            }
        }
        out.reverse();
        out
    }

    /// High-water mark (drives the provisioner).
    pub fn peak(&self) -> usize {
        self.peak
    }

    /// High-water mark since construction or the last call, resetting it
    /// to the current length — the per-interval demand signal the
    /// provisioner evaluates (a burst that arrived and drained between
    /// two evaluations still registers).
    pub fn take_peak(&mut self) -> usize {
        let p = self.peak;
        self.peak = self.len();
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Task, TaskId};

    fn task(id: u64) -> Task {
        Task::with_inputs(TaskId(id), vec![])
    }

    #[test]
    fn fifo_order() {
        let mut q = WaitQueue::new();
        q.push(task(1));
        q.push(task(2));
        assert_eq!(q.pop().unwrap().id, TaskId(1));
        assert_eq!(q.pop().unwrap().id, TaskId(2));
        assert!(q.pop().is_none());
    }

    #[test]
    fn park_and_release_preserves_order() {
        let mut q = WaitQueue::new();
        q.push(task(10));
        q.park(7, task(1));
        q.park(7, task(2));
        assert_eq!(q.len(), 3);
        assert_eq!(q.ready_len(), 1);
        q.release(7);
        // Parked tasks jump the FIFO, in their own admission order.
        assert_eq!(q.pop().unwrap().id, TaskId(1));
        assert_eq!(q.pop().unwrap().id, TaskId(2));
        assert_eq!(q.pop().unwrap().id, TaskId(10));
    }

    #[test]
    fn push_front_requeues() {
        let mut q = WaitQueue::new();
        q.push(task(1));
        q.push(task(2));
        let t = q.pop().unwrap();
        q.push_front(t);
        assert_eq!(q.pop().unwrap().id, TaskId(1));
    }

    #[test]
    fn release_unknown_executor_is_noop() {
        let mut q = WaitQueue::new();
        q.release(99);
        assert!(q.is_empty());
    }

    #[test]
    fn steal_back_takes_youngest_in_order() {
        let mut q = WaitQueue::new();
        for i in 0..5 {
            q.push(task(i));
        }
        q.park(7, task(99));
        let stolen = q.steal_back(3);
        let ids: Vec<u64> = stolen.iter().map(|t| t.id.0).collect();
        // Back of the FIFO (youngest), original relative order kept.
        assert_eq!(ids, vec![2, 3, 4]);
        // Victim keeps its oldest ready tasks and all parked tasks.
        assert_eq!(q.ready_len(), 2);
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop().unwrap().id, TaskId(0));
        // Over-asking drains only what is ready.
        let rest = q.steal_back(10);
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].id, TaskId(1));
        assert_eq!(q.ready_len(), 0);
        assert_eq!(q.len(), 1, "parked task untouched");
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut q = WaitQueue::new();
        for i in 0..5 {
            q.push(task(i));
        }
        for _ in 0..5 {
            q.pop();
        }
        assert_eq!(q.peak(), 5);
        assert!(q.is_empty());
    }

    #[test]
    fn take_peak_resets_to_current_len() {
        let mut q = WaitQueue::new();
        for i in 0..4 {
            q.push(task(i));
        }
        q.pop();
        assert_eq!(q.take_peak(), 4);
        assert_eq!(q.peak(), 3, "reset to current length, not zero");
        q.push(task(9));
        assert_eq!(q.take_peak(), 4);
    }
}
