//! `max-compute-util`: always dispatch to an *available* executor; among
//! the idle candidates pick the one holding the most needed data. Keeps
//! CPUs busy (no delays) while still exploiting locality (§3.2.2). This
//! is the policy the paper uses for all §5 data-diffusion experiments.
//!
//! Scoring runs through [`SchedView::best_holder`] over the *idle* set:
//! O(inputs × replicas) per decision — independent of cluster size —
//! instead of O(executors × inputs). Executors holding none of the
//! inputs all score zero anyway; the first idle executor stands in for
//! them, which is exactly the executor the exhaustive scan would have
//! picked (max over zero scores, ties to the lowest id).

use super::decision::{BatchScratch, Decision, SchedView};
use crate::coordinator::task::Task;

/// Decide per the max-compute-util policy.
pub fn decide(task: &Task, view: &SchedView) -> Decision {
    decide_with(task, view, &mut BatchScratch::default())
}

/// [`decide`] with a caller-owned scoring scratch, so a batched drain
/// scores k tasks against one reused accumulator.
pub fn decide_with(task: &Task, view: &SchedView, scratch: &mut BatchScratch) -> Decision {
    if view.idle.is_empty() {
        return Decision::NoExecutor;
    }
    let executor = match view.best_holder_in(task, view.idle, scratch) {
        // Zero-byte candidates tie with every idle executor; the scan's
        // lowest-id tie-break is the first idle one.
        Some((e, bytes)) if bytes > 0 => e,
        _ => view.idle[0],
    };
    Decision::Dispatch {
        executor,
        hints: view.hints_for(task),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Task, TaskId};
    use crate::index::central::CentralIndex;
    use crate::storage::object::{Catalog, ObjectId};

    #[test]
    fn prefers_idle_executor_with_most_bytes() {
        let mut idx = CentralIndex::new();
        let mut cat = Catalog::new();
        cat.insert(ObjectId(1), 100);
        cat.insert(ObjectId(2), 1);
        idx.insert(ObjectId(1), 2); // 100 bytes on exec 2
        idx.insert(ObjectId(2), 0); // 1 byte on exec 0
        let view = SchedView {
            idle: &[0, 2],
            all: &[0, 2],
            index: &idx,
            catalog: &cat,
        };
        let task = Task::with_inputs(TaskId(1), vec![ObjectId(1), ObjectId(2)]);
        match decide(&task, &view) {
            Decision::Dispatch { executor, .. } => assert_eq!(executor, 2),
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn never_delays_for_busy_holder() {
        let mut idx = CentralIndex::new();
        let mut cat = Catalog::new();
        cat.insert(ObjectId(1), 100);
        idx.insert(ObjectId(1), 9); // best holder is NOT idle
        let view = SchedView {
            idle: &[0],
            all: &[0, 9],
            index: &idx,
            catalog: &cat,
        };
        let task = Task::with_inputs(TaskId(1), vec![ObjectId(1)]);
        match decide(&task, &view) {
            // Must dispatch to an idle executor (0), with a hint pointing
            // at executor 9's cache for a peer fetch.
            Decision::Dispatch { executor, hints } => {
                assert_eq!(executor, 0);
                assert_eq!(hints.get(&ObjectId(1)), Some(&vec![9]));
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn no_idle_means_no_executor() {
        let idx = CentralIndex::new();
        let cat = Catalog::new();
        let view = SchedView {
            idle: &[],
            all: &[1, 2],
            index: &idx,
            catalog: &cat,
        };
        let task = Task::with_inputs(TaskId(1), vec![]);
        assert_eq!(decide(&task, &view), Decision::NoExecutor);
    }

    #[test]
    fn deterministic_tie_break_low_id() {
        let idx = CentralIndex::new();
        let cat = Catalog::new();
        let view = SchedView {
            idle: &[3, 5, 8],
            all: &[3, 5, 8],
            index: &idx,
            catalog: &cat,
        };
        let task = Task::with_inputs(TaskId(1), vec![ObjectId(1)]);
        match decide(&task, &view) {
            Decision::Dispatch { executor, .. } => assert_eq!(executor, 3),
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn equal_bytes_spread_across_replicas_by_task_id() {
        let mut idx = CentralIndex::new();
        let mut cat = Catalog::new();
        cat.insert(ObjectId(1), 10);
        idx.insert(ObjectId(1), 4);
        idx.insert(ObjectId(1), 7); // both idle, same bytes: replicas
        let view = SchedView {
            idle: &[4, 7],
            all: &[4, 7],
            index: &idx,
            catalog: &cat,
        };
        // Consecutive tasks rotate across the tied copies instead of all
        // landing on the lowest id.
        let picks: Vec<_> = (0..2u64)
            .map(
                |i| match decide(&Task::with_inputs(TaskId(i), vec![ObjectId(1)]), &view) {
                    Decision::Dispatch { executor, .. } => executor,
                    other => panic!("unexpected: {other:?}"),
                },
            )
            .collect();
        assert_eq!(picks, vec![4, 7]);
    }
}
