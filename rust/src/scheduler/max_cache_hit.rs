//! `max-cache-hit`: dispatch to the executor holding the most needed
//! data, **even if busy** — in that case dispatch is delayed until it
//! becomes available. Maximizes cache reuse at the risk of load imbalance
//! (§3.2.2).
//!
//! Like `max-compute-util`, scoring runs through
//! [`SchedView::best_holder`] — here over *all* registered executors
//! (busy included) — at O(inputs × replicas) per decision instead of
//! scanning every registered executor. An executor holding nothing can
//! never be "best by cached bytes", so only holders need scoring; the
//! no-holder case falls back to the first idle executor exactly as the
//! exhaustive scan did, and the membership filter ensures the policy
//! never waits on a deregistered ghost.

use super::decision::{BatchScratch, Decision, SchedView};
use crate::coordinator::task::Task;

/// Decide per the max-cache-hit policy.
pub fn decide(task: &Task, view: &SchedView) -> Decision {
    decide_with(task, view, &mut BatchScratch::default())
}

/// [`decide`] with a caller-owned scoring scratch, so a batched drain
/// scores k tasks against one reused accumulator.
pub fn decide_with(task: &Task, view: &SchedView, scratch: &mut BatchScratch) -> Decision {
    match view.best_holder_in(task, view.all, scratch) {
        Some((e, bytes)) if bytes > 0 => {
            if view.idle.binary_search(&e).is_ok() {
                Decision::Dispatch {
                    executor: e,
                    hints: view.hints_for(task),
                }
            } else {
                Decision::Delay { executor: e }
            }
        }
        // Nothing cached anywhere: fall back to first idle executor.
        _ => match view.idle.first() {
            Some(&executor) => Decision::Dispatch {
                executor,
                hints: view.hints_for(task),
            },
            None => Decision::NoExecutor,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Task, TaskId};
    use crate::index::central::CentralIndex;
    use crate::storage::object::{Catalog, ObjectId};

    fn catalog() -> Catalog {
        let mut cat = Catalog::new();
        for i in 1..=4 {
            cat.insert(ObjectId(i), 10);
        }
        cat
    }

    #[test]
    fn waits_for_busy_best_executor() {
        let mut idx = CentralIndex::new();
        idx.insert(ObjectId(1), 3);
        idx.insert(ObjectId(2), 3); // executor 3 holds both inputs...
        let cat = catalog();
        let view = SchedView {
            idle: &[0, 1], // ...but is busy
            all: &[0, 1, 3],
            index: &idx,
            catalog: &cat,
        };
        let task = Task::with_inputs(TaskId(1), vec![ObjectId(1), ObjectId(2)]);
        assert_eq!(decide(&task, &view), Decision::Delay { executor: 3 });
    }

    #[test]
    fn dispatches_to_best_when_idle() {
        let mut idx = CentralIndex::new();
        idx.insert(ObjectId(1), 1);
        let cat = catalog();
        let view = SchedView {
            idle: &[0, 1],
            all: &[0, 1],
            index: &idx,
            catalog: &cat,
        };
        let task = Task::with_inputs(TaskId(1), vec![ObjectId(1)]);
        match decide(&task, &view) {
            Decision::Dispatch { executor, hints } => {
                assert_eq!(executor, 1);
                assert_eq!(hints.get(&ObjectId(1)), Some(&vec![1]));
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn falls_back_to_first_idle_when_nothing_cached() {
        let idx = CentralIndex::new();
        let cat = catalog();
        let view = SchedView {
            idle: &[4, 7],
            all: &[4, 7],
            index: &idx,
            catalog: &cat,
        };
        let task = Task::with_inputs(TaskId(1), vec![ObjectId(1)]);
        match decide(&task, &view) {
            Decision::Dispatch { executor, .. } => assert_eq!(executor, 4),
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn never_waits_on_a_deregistered_holder() {
        let mut idx = CentralIndex::new();
        idx.insert(ObjectId(1), 9); // holder 9 is no longer registered
        let cat = catalog();
        let view = SchedView {
            idle: &[0],
            all: &[0], // 9 absent
            index: &idx,
            catalog: &cat,
        };
        let task = Task::with_inputs(TaskId(1), vec![ObjectId(1)]);
        match decide(&task, &view) {
            Decision::Dispatch { executor, .. } => assert_eq!(executor, 0),
            other => panic!("unexpected: {other:?}"),
        }
    }
}
