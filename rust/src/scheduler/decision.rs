//! Scheduling decision types and the read-only view policies consume.

use std::collections::HashMap;

use crate::coordinator::task::Task;
use crate::index::central::ExecutorId;
use crate::index::DataIndex;
use crate::storage::object::{Catalog, ObjectId};

/// Per-object location hints shipped with a dispatched task, so the
/// executor can fetch from a peer cache without further index lookups
/// (§3.2.2: "the centralized scheduler includes the necessary information
/// to locate needed data ... without further lookups incurred at the
/// executors").
pub type LocationHints = HashMap<ObjectId, Vec<ExecutorId>>;

/// What the dispatcher decided to do with one task.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Decision {
    /// Send the task to `executor` now, with the given data-location hints
    /// (empty for location-unaware policies).
    Dispatch {
        /// Chosen executor.
        executor: ExecutorId,
        /// Object → peer locations map (may be empty).
        hints: LocationHints,
    },
    /// The best executor is busy; hold the task until it reports back
    /// (max-cache-hit only).
    Delay {
        /// The busy executor worth waiting for.
        executor: ExecutorId,
    },
    /// No executor can take the task right now (all busy / none allocated).
    NoExecutor,
}

/// Read-only scheduler inputs.
pub struct SchedView<'a> {
    /// Idle executors, in ascending id order (determinism).
    pub idle: &'a [ExecutorId],
    /// All registered executors (idle + busy), ascending.
    pub all: &'a [ExecutorId],
    /// The cache-location index (any [`DataIndex`] backend; backends may
    /// differ in lookup cost but never in contents — see `crate::index`).
    pub index: &'a dyn DataIndex,
    /// Object size catalog (policies weigh *bytes*, not object counts,
    /// when sizes differ; with uniform sizes this reduces to counts).
    pub catalog: &'a Catalog,
}

impl<'a> SchedView<'a> {
    /// Total cached bytes executor `e` holds out of `task`'s needs.
    pub fn cached_bytes(&self, task: &Task, e: ExecutorId) -> u64 {
        task.inputs
            .iter()
            .filter(|&&obj| self.index.holds(e, obj))
            .map(|&obj| self.catalog.size(obj).unwrap_or(1))
            .sum()
    }

    /// Best executor among `members` (a sorted slice — `idle` or `all`)
    /// by cached bytes over `task`'s inputs, with ties to the lower id.
    ///
    /// Candidates come from `index.locations()` per input, so the cost is
    /// O(inputs × replicas) — independent of cluster size — and executors
    /// holding none of the inputs are never candidates (they all score
    /// zero; callers fall back to the first idle executor, exactly the
    /// executor an exhaustive zero-score scan would tie-break to). The
    /// membership filter also guards against locations that outlived a
    /// deregistration: the scheduler must never target a ghost.
    pub fn best_holder(&self, task: &Task, members: &[ExecutorId]) -> Option<(ExecutorId, u64)> {
        if self.index.is_empty() {
            return None;
        }
        // Tiny linear map: an object rarely lives on more than a few
        // executors.
        let mut per_exec: Vec<(ExecutorId, u64)> = Vec::with_capacity(8);
        for &obj in &task.inputs {
            let size = self.catalog.size(obj).unwrap_or(1);
            for &e in self.index.locations(obj) {
                if members.binary_search(&e).is_err() {
                    continue;
                }
                match per_exec.iter_mut().find(|(pe, _)| *pe == e) {
                    Some((_, s)) => *s += size,
                    None => per_exec.push((e, size)),
                }
            }
        }
        let mut best: Option<(ExecutorId, u64)> = None;
        for &(e, s) in &per_exec {
            let better = match best {
                None => true,
                Some((be, bs)) => s > bs || (s == bs && e < be),
            };
            if better {
                best = Some((e, s));
            }
        }
        best
    }

    /// Build location hints for every input of `task`.
    pub fn hints_for(&self, task: &Task) -> LocationHints {
        let mut hints = LocationHints::new();
        for &obj in &task.inputs {
            let locs = self.index.locations(obj);
            if !locs.is_empty() {
                hints.insert(obj, locs.to_vec());
            }
        }
        hints
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Task, TaskId};
    use crate::index::central::CentralIndex;

    fn setup() -> (CentralIndex, Catalog) {
        let mut idx = CentralIndex::new();
        let mut cat = Catalog::new();
        cat.insert(ObjectId(1), 100);
        cat.insert(ObjectId(2), 50);
        cat.insert(ObjectId(3), 10);
        idx.insert(ObjectId(1), 0);
        idx.insert(ObjectId(2), 0);
        idx.insert(ObjectId(2), 1);
        (idx, cat)
    }

    #[test]
    fn cached_bytes_weighs_sizes() {
        let (idx, cat) = setup();
        let view = SchedView {
            idle: &[0, 1],
            all: &[0, 1],
            index: &idx,
            catalog: &cat,
        };
        let task = Task::with_inputs(TaskId(1), vec![ObjectId(1), ObjectId(2), ObjectId(3)]);
        assert_eq!(view.cached_bytes(&task, 0), 150);
        assert_eq!(view.cached_bytes(&task, 1), 50);
        assert_eq!(view.cached_bytes(&task, 9), 0);
    }

    #[test]
    fn best_holder_scores_members_only_with_low_id_ties() {
        let (idx, cat) = setup();
        let view = SchedView {
            idle: &[0],
            all: &[0, 1],
            index: &idx,
            catalog: &cat,
        };
        // Object 2 (50 B) lives on 0 and 1; object 1 (100 B) only on 0.
        let task = Task::with_inputs(TaskId(1), vec![ObjectId(1), ObjectId(2)]);
        assert_eq!(view.best_holder(&task, view.all), Some((0, 150)));
        // Restricted to a membership slice that excludes executor 0.
        assert_eq!(view.best_holder(&task, &[1]), Some((1, 50)));
        // A tie (object 2 alone) goes to the lower id.
        let tie = Task::with_inputs(TaskId(2), vec![ObjectId(2)]);
        assert_eq!(view.best_holder(&tie, view.all), Some((0, 50)));
        // Nothing held by the members: no candidate.
        let task3 = Task::with_inputs(TaskId(3), vec![ObjectId(3)]);
        assert_eq!(view.best_holder(&task3, view.all), None);
    }

    #[test]
    fn hints_cover_only_located_objects() {
        let (idx, cat) = setup();
        let view = SchedView {
            idle: &[0],
            all: &[0, 1],
            index: &idx,
            catalog: &cat,
        };
        let task = Task::with_inputs(TaskId(1), vec![ObjectId(1), ObjectId(3)]);
        let hints = view.hints_for(&task);
        assert_eq!(hints.get(&ObjectId(1)), Some(&vec![0]));
        assert!(!hints.contains_key(&ObjectId(3)));
    }
}
