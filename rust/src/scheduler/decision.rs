//! Scheduling decision types and the read-only view policies consume.

use std::collections::HashMap;

use crate::coordinator::task::Task;
use crate::index::central::ExecutorId;
use crate::index::DataIndex;
use crate::storage::object::{Catalog, ObjectId};

/// Per-object location hints shipped with a dispatched task, so the
/// executor can fetch from a peer cache without further index lookups
/// (§3.2.2: "the centralized scheduler includes the necessary information
/// to locate needed data ... without further lookups incurred at the
/// executors").
///
/// When an object has multiple holders (replicas), the hint list is
/// *ranked*, not merely sorted: [`SchedView::hints_for`] rotates the
/// ascending holder list by the task id, so consecutive tasks try
/// different replicas first and peer-fetch load spreads across copies
/// instead of hammering the lowest-id holder.
pub type LocationHints = HashMap<ObjectId, Vec<ExecutorId>>;

/// What the dispatcher decided to do with one task.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Decision {
    /// Send the task to `executor` now, with the given data-location hints
    /// (empty for location-unaware policies).
    Dispatch {
        /// Chosen executor.
        executor: ExecutorId,
        /// Object → peer locations map (may be empty).
        hints: LocationHints,
    },
    /// The best executor is busy; hold the task until it reports back
    /// (max-cache-hit only).
    Delay {
        /// The busy executor worth waiting for.
        executor: ExecutorId,
    },
    /// No executor can take the task right now (all busy / none allocated).
    NoExecutor,
}

/// Reusable scoring scratch for batched dispatch.
///
/// Scoring one task builds a tiny executor → cached-bytes map; deciding a
/// whole ready batch per wake-up would otherwise allocate that map k
/// times. The dispatcher owns one `BatchScratch` and threads it through
/// [`DispatchPolicy::decide_with`], so a batch of k decisions reuses a
/// single allocation. Purely an allocation-reuse vehicle: decisions made
/// with or without a scratch are identical by construction
/// ([`SchedView::best_holder`] delegates to [`SchedView::best_holder_in`]
/// with a throwaway scratch).
///
/// [`DispatchPolicy::decide_with`]: super::DispatchPolicy::decide_with
#[derive(Debug, Default)]
pub struct BatchScratch {
    /// Executor → cached bytes accumulator (cleared per decision, the
    /// backing allocation survives across the batch).
    pub per_exec: Vec<(ExecutorId, u64)>,
}

/// Read-only scheduler inputs.
pub struct SchedView<'a> {
    /// Idle executors, in ascending id order (determinism).
    pub idle: &'a [ExecutorId],
    /// All registered executors (idle + busy), ascending.
    pub all: &'a [ExecutorId],
    /// The cache-location index (any [`DataIndex`] backend; backends may
    /// differ in lookup cost but never in contents — see `crate::index`).
    pub index: &'a dyn DataIndex,
    /// Object size catalog (policies weigh *bytes*, not object counts,
    /// when sizes differ; with uniform sizes this reduces to counts).
    pub catalog: &'a Catalog,
}

impl<'a> SchedView<'a> {
    /// Total cached bytes executor `e` holds out of `task`'s needs.
    pub fn cached_bytes(&self, task: &Task, e: ExecutorId) -> u64 {
        task.inputs
            .iter()
            .filter(|&&obj| self.index.holds(e, obj))
            .map(|&obj| self.catalog.size(obj).unwrap_or(1))
            .sum()
    }

    /// Deterministic replica-spreading offset: equivalent replicas are
    /// ranked by rotating the candidate list by the task id, so back-to-
    /// back tasks fan out across copies instead of all picking the
    /// lowest-id holder. Purely a function of task identity and index
    /// *contents* — never of the index backend — so placement stays
    /// backend-invariant and replays identically.
    pub fn spread_offset(task: &Task) -> usize {
        task.id.0 as usize
    }

    /// Best executor among `members` (a sorted slice — `idle` or `all`)
    /// by cached bytes over `task`'s inputs. Ties between executors
    /// holding the *same* cached bytes (replicas of the task's inputs)
    /// rotate by [`SchedView::spread_offset`], spreading load across the
    /// copies the replication manager creates.
    ///
    /// Candidates come from `index.locations()` per input, so the cost is
    /// O(inputs × replicas) — independent of cluster size — and executors
    /// holding none of the inputs are never candidates (they all score
    /// zero; callers fall back to the first idle executor, exactly the
    /// executor an exhaustive zero-score scan would tie-break to). The
    /// membership filter also guards against locations that outlived a
    /// deregistration: the scheduler must never target a ghost.
    pub fn best_holder(&self, task: &Task, members: &[ExecutorId]) -> Option<(ExecutorId, u64)> {
        self.best_holder_in(task, members, &mut BatchScratch::default())
    }

    /// [`best_holder`] with a caller-owned [`BatchScratch`], so batched
    /// dispatch scores k tasks without k map allocations. Identical
    /// decisions — the scratch only recycles the accumulator's backing
    /// storage.
    ///
    /// [`best_holder`]: SchedView::best_holder
    pub fn best_holder_in(
        &self,
        task: &Task,
        members: &[ExecutorId],
        scratch: &mut BatchScratch,
    ) -> Option<(ExecutorId, u64)> {
        if self.index.is_empty() {
            return None;
        }
        // Tiny linear map: an object rarely lives on more than a few
        // executors.
        let per_exec = &mut scratch.per_exec;
        per_exec.clear();
        for &obj in &task.inputs {
            let size = self.catalog.size(obj).unwrap_or(1);
            for &e in self.index.locations(obj) {
                if members.binary_search(&e).is_err() {
                    continue;
                }
                match per_exec.iter_mut().find(|(pe, _)| *pe == e) {
                    Some((_, s)) => *s += size,
                    None => per_exec.push((e, size)),
                }
            }
        }
        Self::rotate_tied(per_exec, task)
    }

    /// The one spread rule: among `scored` executors, pick the max score;
    /// executors tied at the max (replicas of the task's inputs) rotate
    /// by [`SchedView::spread_offset`]. Shared by [`best_holder`] and the
    /// core's wait-queue window scan so the two dispatch paths can never
    /// diverge on how replicas are ranked.
    ///
    /// [`best_holder`]: SchedView::best_holder
    pub fn rotate_tied(scored: &[(ExecutorId, u64)], task: &Task) -> Option<(ExecutorId, u64)> {
        let best = scored.iter().map(|&(_, s)| s).max()?;
        let mut tied: Vec<ExecutorId> = scored
            .iter()
            .filter(|&&(_, s)| s == best)
            .map(|&(e, _)| e)
            .collect();
        tied.sort_unstable();
        Some((tied[Self::spread_offset(task) % tied.len()], best))
    }

    /// Build location hints for every input of `task`, each holder list
    /// ranked by rotating the ascending locations by
    /// [`SchedView::spread_offset`] (executors try the first entry
    /// first, so ranking is what spreads peer-fetch sources).
    pub fn hints_for(&self, task: &Task) -> LocationHints {
        let rot = Self::spread_offset(task);
        let mut hints = LocationHints::new();
        for &obj in &task.inputs {
            let locs = self.index.locations(obj);
            if !locs.is_empty() {
                let r = rot % locs.len();
                let mut ranked = Vec::with_capacity(locs.len());
                ranked.extend_from_slice(&locs[r..]);
                ranked.extend_from_slice(&locs[..r]);
                hints.insert(obj, ranked);
            }
        }
        hints
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Task, TaskId};
    use crate::index::central::CentralIndex;

    fn setup() -> (CentralIndex, Catalog) {
        let mut idx = CentralIndex::new();
        let mut cat = Catalog::new();
        cat.insert(ObjectId(1), 100);
        cat.insert(ObjectId(2), 50);
        cat.insert(ObjectId(3), 10);
        idx.insert(ObjectId(1), 0);
        idx.insert(ObjectId(2), 0);
        idx.insert(ObjectId(2), 1);
        (idx, cat)
    }

    #[test]
    fn cached_bytes_weighs_sizes() {
        let (idx, cat) = setup();
        let view = SchedView {
            idle: &[0, 1],
            all: &[0, 1],
            index: &idx,
            catalog: &cat,
        };
        let task = Task::with_inputs(TaskId(1), vec![ObjectId(1), ObjectId(2), ObjectId(3)]);
        assert_eq!(view.cached_bytes(&task, 0), 150);
        assert_eq!(view.cached_bytes(&task, 1), 50);
        assert_eq!(view.cached_bytes(&task, 9), 0);
    }

    #[test]
    fn best_holder_scores_members_and_rotates_replica_ties() {
        let (idx, cat) = setup();
        let view = SchedView {
            idle: &[0],
            all: &[0, 1],
            index: &idx,
            catalog: &cat,
        };
        // Object 2 (50 B) lives on 0 and 1; object 1 (100 B) only on 0.
        let task = Task::with_inputs(TaskId(1), vec![ObjectId(1), ObjectId(2)]);
        assert_eq!(view.best_holder(&task, view.all), Some((0, 150)));
        // Restricted to a membership slice that excludes executor 0.
        assert_eq!(view.best_holder(&task, &[1]), Some((1, 50)));
        // Replica ties (object 2 alone, held by 0 and 1) rotate by task
        // id: even tasks hit one copy, odd tasks the other.
        let tie = Task::with_inputs(TaskId(2), vec![ObjectId(2)]);
        assert_eq!(view.best_holder(&tie, view.all), Some((0, 50)));
        let tie = Task::with_inputs(TaskId(3), vec![ObjectId(2)]);
        assert_eq!(view.best_holder(&tie, view.all), Some((1, 50)));
        // Nothing held by the members: no candidate.
        let task3 = Task::with_inputs(TaskId(4), vec![ObjectId(3)]);
        assert_eq!(view.best_holder(&task3, view.all), None);
    }

    #[test]
    fn best_holder_in_matches_best_holder_across_a_batch() {
        let (idx, cat) = setup();
        let view = SchedView {
            idle: &[0, 1],
            all: &[0, 1],
            index: &idx,
            catalog: &cat,
        };
        let mut scratch = BatchScratch::default();
        for id in 0..8u64 {
            let task = Task::with_inputs(TaskId(id), vec![ObjectId(1), ObjectId(2)]);
            assert_eq!(
                view.best_holder_in(&task, view.all, &mut scratch),
                view.best_holder(&task, view.all),
                "scratch reuse must not change the decision (task {id})"
            );
        }
    }

    #[test]
    fn hints_cover_only_located_objects() {
        let (idx, cat) = setup();
        let view = SchedView {
            idle: &[0],
            all: &[0, 1],
            index: &idx,
            catalog: &cat,
        };
        let task = Task::with_inputs(TaskId(1), vec![ObjectId(1), ObjectId(3)]);
        let hints = view.hints_for(&task);
        assert_eq!(hints.get(&ObjectId(1)), Some(&vec![0]));
        assert!(!hints.contains_key(&ObjectId(3)));
    }

    #[test]
    fn hints_rank_replicas_by_task_id() {
        let (idx, cat) = setup();
        let view = SchedView {
            idle: &[0],
            all: &[0, 1],
            index: &idx,
            catalog: &cat,
        };
        // Object 2 lives on 0 and 1: even task ids rank 0 first, odd 1.
        let even = view.hints_for(&Task::with_inputs(TaskId(2), vec![ObjectId(2)]));
        assert_eq!(even.get(&ObjectId(2)), Some(&vec![0, 1]));
        let odd = view.hints_for(&Task::with_inputs(TaskId(3), vec![ObjectId(2)]));
        assert_eq!(odd.get(&ObjectId(2)), Some(&vec![1, 0]));
    }
}
