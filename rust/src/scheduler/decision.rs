//! Scheduling decision types and the read-only view policies consume.

use std::collections::HashMap;

use crate::coordinator::task::Task;
use crate::index::central::ExecutorId;
use crate::index::DataIndex;
use crate::storage::object::{Catalog, ObjectId};

/// Per-object location hints shipped with a dispatched task, so the
/// executor can fetch from a peer cache without further index lookups
/// (§3.2.2: "the centralized scheduler includes the necessary information
/// to locate needed data ... without further lookups incurred at the
/// executors").
pub type LocationHints = HashMap<ObjectId, Vec<ExecutorId>>;

/// What the dispatcher decided to do with one task.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Decision {
    /// Send the task to `executor` now, with the given data-location hints
    /// (empty for location-unaware policies).
    Dispatch {
        /// Chosen executor.
        executor: ExecutorId,
        /// Object → peer locations map (may be empty).
        hints: LocationHints,
    },
    /// The best executor is busy; hold the task until it reports back
    /// (max-cache-hit only).
    Delay {
        /// The busy executor worth waiting for.
        executor: ExecutorId,
    },
    /// No executor can take the task right now (all busy / none allocated).
    NoExecutor,
}

/// Read-only scheduler inputs.
pub struct SchedView<'a> {
    /// Idle executors, in ascending id order (determinism).
    pub idle: &'a [ExecutorId],
    /// All registered executors (idle + busy), ascending.
    pub all: &'a [ExecutorId],
    /// The cache-location index (any [`DataIndex`] backend; backends may
    /// differ in lookup cost but never in contents — see `crate::index`).
    pub index: &'a dyn DataIndex,
    /// Object size catalog (policies weigh *bytes*, not object counts,
    /// when sizes differ; with uniform sizes this reduces to counts).
    pub catalog: &'a Catalog,
}

impl<'a> SchedView<'a> {
    /// Total cached bytes executor `e` holds out of `task`'s needs.
    pub fn cached_bytes(&self, task: &Task, e: ExecutorId) -> u64 {
        task.inputs
            .iter()
            .filter(|&&obj| self.index.holds(e, obj))
            .map(|&obj| self.catalog.size(obj).unwrap_or(1))
            .sum()
    }

    /// Build location hints for every input of `task`.
    pub fn hints_for(&self, task: &Task) -> LocationHints {
        let mut hints = LocationHints::new();
        for &obj in &task.inputs {
            let locs = self.index.locations(obj);
            if !locs.is_empty() {
                hints.insert(obj, locs.to_vec());
            }
        }
        hints
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Task, TaskId};
    use crate::index::central::CentralIndex;

    fn setup() -> (CentralIndex, Catalog) {
        let mut idx = CentralIndex::new();
        let mut cat = Catalog::new();
        cat.insert(ObjectId(1), 100);
        cat.insert(ObjectId(2), 50);
        cat.insert(ObjectId(3), 10);
        idx.insert(ObjectId(1), 0);
        idx.insert(ObjectId(2), 0);
        idx.insert(ObjectId(2), 1);
        (idx, cat)
    }

    #[test]
    fn cached_bytes_weighs_sizes() {
        let (idx, cat) = setup();
        let view = SchedView {
            idle: &[0, 1],
            all: &[0, 1],
            index: &idx,
            catalog: &cat,
        };
        let task = Task::with_inputs(TaskId(1), vec![ObjectId(1), ObjectId(2), ObjectId(3)]);
        assert_eq!(view.cached_bytes(&task, 0), 150);
        assert_eq!(view.cached_bytes(&task, 1), 50);
        assert_eq!(view.cached_bytes(&task, 9), 0);
    }

    #[test]
    fn hints_cover_only_located_objects() {
        let (idx, cat) = setup();
        let view = SchedView {
            idle: &[0],
            all: &[0, 1],
            index: &idx,
            catalog: &cat,
        };
        let task = Task::with_inputs(TaskId(1), vec![ObjectId(1), ObjectId(3)]);
        let hints = view.hints_for(&task);
        assert_eq!(hints.get(&ObjectId(1)), Some(&vec![0]));
        assert!(!hints.contains_key(&ObjectId(3)));
    }
}
