//! `first-available`: location-unaware dispatch, no hints.
//!
//! "ignores data location information ... simply chooses the first
//! available executor, and furthermore provides the executor with no
//! information concerning the location of data objects needed by the
//! task. Thus, the executor must fetch all data needed by a task from
//! persistent storage on every access."

use super::decision::{BatchScratch, Decision, LocationHints, SchedView};
use crate::coordinator::task::Task;

/// Decide per the first-available policy.
pub fn decide(task: &Task, view: &SchedView) -> Decision {
    decide_with(task, view, &mut BatchScratch::default())
}

/// [`decide`] with a caller-owned scoring scratch (unused here: the
/// policy never scores holders, but the batched dispatcher threads one
/// scratch through every policy uniformly).
pub fn decide_with(_task: &Task, view: &SchedView, _scratch: &mut BatchScratch) -> Decision {
    match view.idle.first() {
        Some(&executor) => Decision::Dispatch {
            executor,
            hints: LocationHints::new(),
        },
        None => Decision::NoExecutor,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Task, TaskId};
    use crate::index::central::CentralIndex;
    use crate::storage::object::{Catalog, ObjectId};

    #[test]
    fn picks_first_idle_without_hints() {
        let mut idx = CentralIndex::new();
        idx.insert(ObjectId(1), 5); // data lives on 5, but policy ignores it
        let cat = Catalog::new();
        let view = SchedView {
            idle: &[2, 5],
            all: &[0, 1, 2, 5],
            index: &idx,
            catalog: &cat,
        };
        let task = Task::with_inputs(TaskId(1), vec![ObjectId(1)]);
        match decide(&task, &view) {
            Decision::Dispatch { executor, hints } => {
                assert_eq!(executor, 2);
                assert!(hints.is_empty(), "first-available must not ship hints");
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn no_idle_executor() {
        let idx = CentralIndex::new();
        let cat = Catalog::new();
        let view = SchedView {
            idle: &[],
            all: &[0],
            index: &idx,
            catalog: &cat,
        };
        let task = Task::with_inputs(TaskId(1), vec![]);
        assert_eq!(decide(&task, &view), Decision::NoExecutor);
    }
}
