"""Layer-2 JAX model: the image-stacking compute graph.

The paper's stacking application (§5) processes, per task, a stack of image
cutouts belonging to one sky object: convert raw SHORT pixels, calibrate,
sub-pixel-shift, and coadd (``stack_pallas``), plus the ``radec2xy``
coordinate transform used to locate each object on its source images.

These functions are **build-time only**: ``aot.py`` lowers them once to HLO
text under ``artifacts/`` and the Rust runtime (``rust/src/runtime``)
executes the artifacts via PJRT. Python never runs on the request path.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels.stacking import stack_pallas

__all__ = ["stack_object", "radec2xy", "STACK_VARIANTS", "ROI_H", "ROI_W"]

# Fixed ROI geometry, matching the paper's profiling setup (§5.2: "1000
# objects of 100x100 pixels").
ROI_H = 100
ROI_W = 100

# AOT stack-depth variants. The Rust runtime picks the smallest variant
# >= the task's stack depth and zero-weights the padded slots (Table 2
# localities range 1..30, so 32 covers every workload in the paper).
STACK_VARIANTS = (1, 2, 4, 8, 16, 32)


def stack_object(
    raw_short: jnp.ndarray,
    sky: jnp.ndarray,
    cal: jnp.ndarray,
    shifts: jnp.ndarray,
    weights: jnp.ndarray,
) -> tuple[jnp.ndarray]:
    """Full per-object stacking graph.

    Mirrors the paper's phase breakdown (§5.2): *convertArray* (SHORT →
    float), then the fused Pallas kernel for *calibration + interpolation +
    doStacking*. (*open/readHDU/getTile* are I/O phases owned by the Rust
    executor; *radec2xy* is a separate artifact.)

    Args:
      raw_short: ``[N, H, W]`` int16 raw pixels as read from the file.
      sky:       ``[N]`` float32 sky levels.
      cal:       ``[N]`` float32 calibration gains.
      shifts:    ``[N, 2]`` float32 sub-pixel offsets.
      weights:   ``[N]`` float32 coadd weights (0 ⇒ padded slot).

    Returns:
      1-tuple of ``[H, W]`` float32 stacked image (tuple because the AOT
      bridge lowers with ``return_tuple=True``; see ``aot.py``).
    """
    # convertArray: SHORT -> float (the paper converts to DOUBLE; we stack
    # in f32 — the XLA CPU backend computes the same graph and the oracle
    # uses the same dtype, so the comparison is dtype-consistent).
    raw = raw_short.astype(jnp.float32)
    return (stack_pallas(raw, sky, cal, shifts, weights),)


def radec2xy(
    ra: jnp.ndarray,
    dec: jnp.ndarray,
    ra0: jnp.ndarray,
    dec0: jnp.ndarray,
    scale: jnp.ndarray,
) -> tuple[jnp.ndarray]:
    """Gnomonic projection of ``M`` object coordinates to pixel (x, y).

    The paper's *radec2xy* phase. Kept as its own artifact because the Rust
    executor calls it once per task batch, before any file I/O.

    Args:
      ra, dec: ``[M]`` float32 coordinates in radians.
      ra0, dec0, scale: scalars — tangent point and pixels-per-radian.

    Returns:
      1-tuple of ``[M, 2]`` float32 pixel coordinates.
    """
    cos_c = jnp.sin(dec0) * jnp.sin(dec) + jnp.cos(dec0) * jnp.cos(dec) * jnp.cos(ra - ra0)
    x = jnp.cos(dec) * jnp.sin(ra - ra0) / cos_c
    y = (jnp.cos(dec0) * jnp.sin(dec) - jnp.sin(dec0) * jnp.cos(dec) * jnp.cos(ra - ra0)) / cos_c
    return (jnp.stack([x * scale, y * scale], axis=-1),)
