"""AOT bridge: lower the L2 jax graphs to HLO text for the Rust runtime.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md.

Outputs (``make artifacts``):

  artifacts/stack_n<N>.hlo.txt     one per stack-depth variant
  artifacts/radec2xy_m<M>.hlo.txt  coordinate-transform artifact
  artifacts/manifest.tsv           machine-readable index for Rust
  artifacts/golden_stack.tsv       golden numerics for the Rust runtime test

The manifest is TSV (not JSON) because the Rust side parses it with the
std library only — no serde in this offline environment.

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import ref

# M variants for the radec2xy artifact (objects per task batch).
RADEC_VARIANTS = (128,)


def to_hlo_text(lowered) -> str:
    """Convert a jax ``Lowered`` to XLA HLO text via stablehlo."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stack(n: int) -> str:
    """Lower ``stack_object`` for stack depth ``n``."""
    h, w = model.ROI_H, model.ROI_W
    args = (
        jax.ShapeDtypeStruct((n, h, w), jnp.int16),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n, 2), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    )
    return to_hlo_text(jax.jit(model.stack_object).lower(*args))


def lower_radec2xy(m: int) -> str:
    """Lower ``radec2xy`` for batch size ``m``."""
    args = (
        jax.ShapeDtypeStruct((m,), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    return to_hlo_text(jax.jit(model.radec2xy).lower(*args))


def golden_stack_fixture(n: int = 4, h: int = None, w: int = None) -> str:
    """Deterministic input/output pairs for the Rust runtime integration test.

    Produces a TSV with the flattened inputs and the *reference* (pure-jnp)
    output so Rust can verify its PJRT execution end-to-end without Python
    at test time. Uses a small ROI variant? No — uses the real artifact
    shape so the same HLO file is exercised.
    """
    h = h or model.ROI_H
    w = w or model.ROI_W
    key = jax.random.PRNGKey(20080610)  # paper's publication year/month
    k1, k2, k3, k4 = jax.random.split(key, 4)
    raw = jax.random.randint(k1, (n, h, w), 0, 4096, dtype=jnp.int16)
    sky = jax.random.uniform(k2, (n,), jnp.float32, 10.0, 100.0)
    cal = jax.random.uniform(k3, (n,), jnp.float32, 0.5, 2.0)
    shifts = jax.random.uniform(k4, (n, 2), jnp.float32, 0.0, 1.0)
    weights = jnp.ones((n,), jnp.float32)
    out = ref.stack_ref(raw.astype(jnp.float32), sky, cal, shifts, weights)

    def row(name, arr):
        flat = jnp.ravel(arr)
        return name + "\t" + " ".join(repr(float(v)) for v in flat)

    lines = [
        f"# golden fixture for stack_n{n} ({h}x{w}); inputs + ref output",
        f"shape\t{n} {h} {w}",
        row("raw", raw),
        row("sky", sky),
        row("cal", cal),
        row("shifts", shifts),
        row("weights", weights),
        row("output", out),
    ]
    return "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default=",".join(str(n) for n in model.STACK_VARIANTS),
        help="comma-separated stack-depth variants to lower",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_rows = []  # kind, name, path, params...

    for n in (int(s) for s in args.variants.split(",")):
        name = f"stack_n{n}"
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = lower_stack(n)
        with open(path, "w") as f:
            f.write(text)
        manifest_rows.append(
            ("stack", name, f"{name}.hlo.txt", f"n={n}", f"h={model.ROI_H}", f"w={model.ROI_W}")
        )
        print(f"wrote {path} ({len(text)} chars)")

    for m in RADEC_VARIANTS:
        name = f"radec2xy_m{m}"
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = lower_radec2xy(m)
        with open(path, "w") as f:
            f.write(text)
        manifest_rows.append(("radec2xy", name, f"{name}.hlo.txt", f"m={m}"))
        print(f"wrote {path} ({len(text)} chars)")

    golden_n = 4
    golden_path = os.path.join(args.out_dir, "golden_stack.tsv")
    with open(golden_path, "w") as f:
        f.write(golden_stack_fixture(golden_n))
    print(f"wrote {golden_path}")

    manifest_path = os.path.join(args.out_dir, "manifest.tsv")
    with open(manifest_path, "w") as f:
        f.write("# kind\tname\tfile\tparams...\n")
        for row in manifest_rows:
            f.write("\t".join(row) + "\n")
    print(f"wrote {manifest_path} ({len(manifest_rows)} artifacts)")


if __name__ == "__main__":
    main()
