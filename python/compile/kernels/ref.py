"""Pure-jnp reference oracle for the stacking kernel.

This module is the correctness ground truth for the Pallas kernel in
``stacking.py``: pytest (``python/tests/test_kernel.py``) sweeps shapes and
parameter ranges with hypothesis and asserts ``assert_allclose`` between the
two implementations.

The computation reproduces the per-stack hot loop of the paper's astronomy
image-stacking application (§5.2 of Raicu et al. 2008):

  1. *calibration*   — ``img = (raw - SKY) * CAL`` per source image,
  2. *interpolation* — bilinear sub-pixel shift by ``(dx, dy)`` so the
     object center lands on a whole pixel,
  3. *doStacking*    — weighted accumulation over the stack followed by
     normalization by the total weight.

Everything here is plain ``jax.numpy`` — no Pallas — so it lowers to
straightforward XLA ops and serves as an independent oracle.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "calibrate",
    "bilinear_shift",
    "stack_ref",
    "radec2xy_ref",
]


def calibrate(raw: jnp.ndarray, sky: jnp.ndarray, cal: jnp.ndarray) -> jnp.ndarray:
    """Apply per-image calibration: ``(raw - sky) * cal``.

    Args:
      raw: ``[N, H, W]`` raw pixel values (already converted to float).
      sky: ``[N]`` per-image sky background level (SKY variable).
      cal: ``[N]`` per-image calibration gain (CAL variable).

    Returns:
      ``[N, H, W]`` calibrated pixels.
    """
    return (raw - sky[:, None, None]) * cal[:, None, None]


def _shift_rows(img: jnp.ndarray) -> jnp.ndarray:
    """Rows shifted up by one pixel with edge-clamp: out[i] = img[i+1]."""
    return jnp.concatenate([img[1:, :], img[-1:, :]], axis=0)


def _shift_cols(img: jnp.ndarray) -> jnp.ndarray:
    """Cols shifted left by one pixel with edge-clamp: out[:, j] = img[:, j+1]."""
    return jnp.concatenate([img[:, 1:], img[:, -1:]], axis=1)


def bilinear_shift(img: jnp.ndarray, dx: jnp.ndarray, dy: jnp.ndarray) -> jnp.ndarray:
    """Bilinearly interpolate ``img`` shifted by a sub-pixel offset.

    ``out[i, j] ≈ img[i + dy, j + dx]`` for ``dx, dy ∈ [0, 1)``, with
    replicated borders. This matches the paper's *interpolation* phase:
    "do the appropriate pixel shifting to ensure the center of the object
    is a whole pixel".

    Args:
      img: ``[H, W]`` single image.
      dx:  scalar horizontal sub-pixel offset in ``[0, 1)``.
      dy:  scalar vertical sub-pixel offset in ``[0, 1)``.

    Returns:
      ``[H, W]`` shifted image.
    """
    right = _shift_cols(img)            # img[i, j+1]
    down = _shift_rows(img)             # img[i+1, j]
    down_right = _shift_cols(down)      # img[i+1, j+1]
    w00 = (1.0 - dy) * (1.0 - dx)
    w01 = (1.0 - dy) * dx
    w10 = dy * (1.0 - dx)
    w11 = dy * dx
    return w00 * img + w01 * right + w10 * down + w11 * down_right


def stack_ref(
    rois: jnp.ndarray,
    sky: jnp.ndarray,
    cal: jnp.ndarray,
    shifts: jnp.ndarray,
    weights: jnp.ndarray,
) -> jnp.ndarray:
    """Reference stacking: calibrate, shift, weighted-coadd, normalize.

    Args:
      rois:    ``[N, H, W]`` raw region-of-interest cutouts.
      sky:     ``[N]`` sky levels.
      cal:     ``[N]`` calibration gains.
      shifts:  ``[N, 2]`` per-image ``(dx, dy)`` sub-pixel offsets.
      weights: ``[N]`` per-image weights; ``0.0`` marks padding entries so
               a fixed-shape AOT artifact can serve variable stack depths.

    Returns:
      ``[H, W]`` stacked image:
      ``sum_i w_i * shift(cal(roi_i)) / max(sum_i w_i, eps)``.
    """
    calibrated = calibrate(rois, sky, cal)
    n = rois.shape[0]
    acc = jnp.zeros(rois.shape[1:], dtype=rois.dtype)
    for i in range(n):
        shifted = bilinear_shift(calibrated[i], shifts[i, 0], shifts[i, 1])
        acc = acc + weights[i] * shifted
    total = jnp.maximum(jnp.sum(weights), jnp.asarray(1e-12, rois.dtype))
    return acc / total


def radec2xy_ref(
    ra: jnp.ndarray,
    dec: jnp.ndarray,
    ra0: jnp.ndarray,
    dec0: jnp.ndarray,
    scale: jnp.ndarray,
) -> jnp.ndarray:
    """Gnomonic (tangent-plane) projection of sky coordinates to pixels.

    Reference for the paper's *radec2xy* phase ("convert coordinates from
    RA DEC to X Y"). Standard gnomonic projection about a tangent point
    ``(ra0, dec0)`` with ``scale`` pixels per radian.

    Args:
      ra, dec: ``[M]`` object coordinates in radians.
      ra0, dec0: scalars, tangent point in radians.
      scale: scalar, pixels per radian.

    Returns:
      ``[M, 2]`` pixel coordinates ``(x, y)``.
    """
    cos_c = jnp.sin(dec0) * jnp.sin(dec) + jnp.cos(dec0) * jnp.cos(dec) * jnp.cos(ra - ra0)
    x = jnp.cos(dec) * jnp.sin(ra - ra0) / cos_c
    y = (jnp.cos(dec0) * jnp.sin(dec) - jnp.sin(dec0) * jnp.cos(dec) * jnp.cos(ra - ra0)) / cos_c
    return jnp.stack([x * scale, y * scale], axis=-1)
