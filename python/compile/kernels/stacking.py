"""Layer-1 Pallas kernel: calibrate + sub-pixel shift + weighted coadd.

This is the compute hot-spot of the paper's image-stacking application
(§5.2: ``calibration + interpolation + doStacking``), written as a single
Pallas kernel so the whole per-stack loop lowers into one fused unit inside
the L2 jax graph.

TPU-shaped design (see DESIGN.md §Hardware-Adaptation):

* The grid iterates over the **stack dimension** ``N`` — one ROI per grid
  step — so only one ``(H, W)`` tile plus the running accumulator live in
  VMEM at a time. For the paper's 100×100 f32 cutouts that is ~40 KB of
  input tile + ~40 KB accumulator, far under the ~16 MB VMEM budget; the
  BlockSpec schedule streams ROIs HBM→VMEM while the previous tile is being
  reduced (the hardware pipeliner double-buffers automatically).
* The work is elementwise + 1-pixel-neighbor stencils, so the target unit
  is the **VPU** (8×128 vector lanes), not the MXU — there is no matmul to
  feed the systolic array. Tiles are kept contiguous in the last dimension
  so lane vectorization is trivial; neighbor fetches are concat-of-slices
  (static shuffles), not dynamic gathers.
* The output block index is constant ``(0, 0)`` across grid steps, which is
  the canonical Pallas accumulation pattern: the same VMEM buffer is
  revisited every step and flushed to HBM once at the end.

``interpret=True`` is mandatory in this environment: real TPU lowering
emits a Mosaic custom-call that the CPU PJRT plugin cannot execute. The
kernel is structured exactly as it would be for hardware; only the
execution mode differs.

Correctness oracle: ``ref.stack_ref`` (pure jnp), enforced by
``python/tests/test_kernel.py`` with hypothesis shape/value sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["stack_pallas"]


def _stack_kernel(roi_ref, sky_ref, cal_ref, shift_ref, weight_ref,
                  weight_all_ref, out_ref):
    """Kernel body: one grid step processes one ROI of the stack.

    Refs (shapes are the *block* shapes chosen in :func:`stack_pallas`):
      roi_ref:        [1, H, W]  raw cutout for this grid step
      sky_ref:        [1]        sky level
      cal_ref:        [1]        calibration gain
      shift_ref:      [1, 2]     (dx, dy) sub-pixel offset
      weight_ref:     [1]        coadd weight (0.0 ⇒ padding entry)
      weight_all_ref: [N]        full weight vector (final normalization)
      out_ref:        [H, W]     accumulator block (same block every step)
    """
    k = pl.program_id(0)
    n = pl.num_programs(0)

    # Zero the accumulator on the first visit. The output BlockSpec maps
    # every grid step to block (0, 0), so out_ref is the same VMEM buffer
    # throughout the grid — the standard Pallas reduction idiom.
    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    raw = roi_ref[0, :, :]
    sky = sky_ref[0]
    cal = cal_ref[0]
    dx = shift_ref[0, 0]
    dy = shift_ref[0, 1]
    w = weight_ref[0]

    # -- calibration: (raw - SKY) * CAL ------------------------------------
    img = (raw - sky) * cal

    # -- interpolation: bilinear sub-pixel shift, replicated borders -------
    right = jnp.concatenate([img[:, 1:], img[:, -1:]], axis=1)        # img[i, j+1]
    down = jnp.concatenate([img[1:, :], img[-1:, :]], axis=0)         # img[i+1, j]
    down_right = jnp.concatenate([down[:, 1:], down[:, -1:]], axis=1)  # img[i+1, j+1]
    w00 = (1.0 - dy) * (1.0 - dx)
    w01 = (1.0 - dy) * dx
    w10 = dy * (1.0 - dx)
    w11 = dy * dx
    shifted = w00 * img + w01 * right + w10 * down + w11 * down_right

    # -- doStacking: weighted accumulate -----------------------------------
    out_ref[...] += w * shifted

    # Normalize by total weight on the final step. The total is recomputed
    # from the full (small: [N]) weight vector — N scalar adds, once.
    @pl.when(k == n - 1)
    def _finalize():
        total = jnp.maximum(jnp.sum(weight_all_ref[...]), 1e-12)
        out_ref[...] = out_ref[...] / total


def stack_pallas(
    rois: jnp.ndarray,
    sky: jnp.ndarray,
    cal: jnp.ndarray,
    shifts: jnp.ndarray,
    weights: jnp.ndarray,
) -> jnp.ndarray:
    """Stack a batch of ROIs with per-image calibration and sub-pixel shift.

    Pallas-kernel equivalent of :func:`ref.stack_ref`.

    Args:
      rois:    ``[N, H, W]`` float32 raw cutouts.
      sky:     ``[N]`` float32 sky levels.
      cal:     ``[N]`` float32 calibration gains.
      shifts:  ``[N, 2]`` float32 ``(dx, dy)`` offsets in ``[0, 1)``.
      weights: ``[N]`` float32 coadd weights (0 ⇒ padded slot).

    Returns:
      ``[H, W]`` float32 stacked image.
    """
    n, h, w = rois.shape
    return pl.pallas_call(
        _stack_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h, w), lambda k: (k, 0, 0)),
            pl.BlockSpec((1,), lambda k: (k,)),
            pl.BlockSpec((1,), lambda k: (k,)),
            pl.BlockSpec((1, 2), lambda k: (k, 0)),
            pl.BlockSpec((1,), lambda k: (k,)),
            # The full weight vector rides along as a second view of the
            # same operand so the final grid step can normalize without a
            # scratch accumulator.
            pl.BlockSpec((n,), lambda k: (0,)),
        ],
        out_specs=pl.BlockSpec((h, w), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), rois.dtype),
        interpret=True,
    )(rois, sky, cal, shifts, weights, weights)
