"""L2 model semantics: stack_object and radec2xy."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import radec2xy_ref, stack_ref

jax.config.update("jax_platform_name", "cpu")


def test_stack_object_converts_short_and_matches_ref():
    rng = np.random.default_rng(7)
    n, h, w = 4, model.ROI_H, model.ROI_W
    raw_short = jnp.asarray(rng.integers(0, 4096, size=(n, h, w), dtype=np.int16))
    sky = jnp.asarray(rng.uniform(0, 100, (n,)).astype(np.float32))
    cal = jnp.asarray(rng.uniform(0.5, 2, (n,)).astype(np.float32))
    shifts = jnp.asarray(rng.uniform(0, 1, (n, 2)).astype(np.float32))
    weights = jnp.ones((n,), jnp.float32)
    (out,) = model.stack_object(raw_short, sky, cal, shifts, weights)
    want = stack_ref(raw_short.astype(jnp.float32), sky, cal, shifts, weights)
    assert out.shape == (h, w)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


def test_stack_variants_cover_table2_localities():
    """Variants must cover stack depths up to Table 2's max locality (30)."""
    assert max(model.STACK_VARIANTS) >= 30
    assert min(model.STACK_VARIANTS) == 1
    assert list(model.STACK_VARIANTS) == sorted(model.STACK_VARIANTS)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 64))
def test_radec2xy_matches_ref(seed, m):
    rng = np.random.default_rng(seed)
    ra = jnp.asarray(rng.uniform(0, 0.3, (m,)).astype(np.float32))
    dec = jnp.asarray(rng.uniform(-0.3, 0.3, (m,)).astype(np.float32))
    ra0 = jnp.float32(0.15)
    dec0 = jnp.float32(0.0)
    scale = jnp.float32(1e4)
    (got,) = model.radec2xy(ra, dec, ra0, dec0, scale)
    want = radec2xy_ref(ra, dec, ra0, dec0, scale)
    assert got.shape == (m, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


def test_radec2xy_tangent_point_maps_to_origin():
    (out,) = model.radec2xy(
        jnp.asarray([0.2], jnp.float32), jnp.asarray([0.1], jnp.float32),
        jnp.float32(0.2), jnp.float32(0.1), jnp.float32(1e4))
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-3)
