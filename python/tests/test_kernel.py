"""L1 correctness: Pallas stacking kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and parameter ranges; every case asserts
``assert_allclose`` between ``stack_pallas`` (interpret mode) and
``ref.stack_ref``. This is the core correctness signal for the compute
layer — the AOT artifacts lower exactly this kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import bilinear_shift, calibrate, stack_ref
from compile.kernels.stacking import stack_pallas

jax.config.update("jax_platform_name", "cpu")


def make_inputs(seed, n, h, w, pad=0):
    rng = np.random.default_rng(seed)
    raw = rng.uniform(0.0, 4096.0, size=(n, h, w)).astype(np.float32)
    sky = rng.uniform(0.0, 200.0, size=(n,)).astype(np.float32)
    cal = rng.uniform(0.25, 4.0, size=(n,)).astype(np.float32)
    shifts = rng.uniform(0.0, 1.0, size=(n, 2)).astype(np.float32)
    weights = np.ones((n,), np.float32)
    if pad:
        weights[n - pad:] = 0.0
    return (jnp.asarray(raw), jnp.asarray(sky), jnp.asarray(cal),
            jnp.asarray(shifts), jnp.asarray(weights))


def assert_matches_ref(args):
    got = stack_pallas(*args)
    want = stack_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 12),
    h=st.integers(2, 24),
    w=st.integers(2, 24),
)
def test_kernel_matches_ref_shapes(seed, n, h, w):
    """Kernel == oracle across random shapes and values."""
    assert_matches_ref(make_inputs(seed, n, h, w))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 8),
       pad=st.integers(1, 3))
def test_kernel_padding_via_zero_weights(seed, n, pad):
    """Zero-weight (padded) slots must not perturb the stacked image."""
    pad = min(pad, n - 1)
    args = make_inputs(seed, n, 8, 8, pad=pad)
    assert_matches_ref(args)
    # And equals the unpadded stack of the first n-pad images.
    raw, sky, cal, shifts, weights = args
    trimmed = (raw[: n - pad], sky[: n - pad], cal[: n - pad],
               shifts[: n - pad], weights[: n - pad])
    np.testing.assert_allclose(
        np.asarray(stack_pallas(*args)),
        np.asarray(stack_ref(*trimmed)),
        rtol=1e-5, atol=1e-4,
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_zero_shift_is_calibrated_mean(seed):
    """With dx=dy=0, stacking = mean of calibrated images (no resampling)."""
    rng = np.random.default_rng(seed)
    n, h, w = 4, 10, 10
    raw = jnp.asarray(rng.uniform(0, 100, size=(n, h, w)).astype(np.float32))
    sky = jnp.asarray(rng.uniform(0, 10, size=(n,)).astype(np.float32))
    cal = jnp.asarray(rng.uniform(0.5, 2, size=(n,)).astype(np.float32))
    shifts = jnp.zeros((n, 2), jnp.float32)
    weights = jnp.ones((n,), jnp.float32)
    got = stack_pallas(raw, sky, cal, shifts, weights)
    want = jnp.mean(calibrate(raw, sky, cal), axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_single_image_identity():
    """Depth-1 stack with no shift and unit cal returns the raw image."""
    raw = jnp.arange(36, dtype=jnp.float32).reshape(1, 6, 6)
    out = stack_pallas(raw, jnp.zeros(1), jnp.ones(1),
                       jnp.zeros((1, 2)), jnp.ones(1))
    np.testing.assert_allclose(np.asarray(out), np.asarray(raw[0]),
                               rtol=1e-6, atol=1e-5)


def test_bilinear_shift_constant_invariant():
    """Shifting a constant image changes nothing (border replication)."""
    img = jnp.full((9, 9), 3.25, jnp.float32)
    out = bilinear_shift(img, jnp.float32(0.37), jnp.float32(0.81))
    np.testing.assert_allclose(np.asarray(out), 3.25, rtol=1e-6)


def test_weighted_average_normalization():
    """Weights of 2.0 on identical images equal the single image."""
    img = jnp.ones((1, 4, 4), jnp.float32) * 7.0
    raw = jnp.concatenate([img, img], axis=0)
    out = stack_pallas(raw, jnp.zeros(2), jnp.ones(2),
                       jnp.zeros((2, 2)), jnp.asarray([2.0, 2.0], jnp.float32))
    np.testing.assert_allclose(np.asarray(out), 7.0, rtol=1e-6)


def test_paper_roi_geometry():
    """The paper's 100x100 ROI at depth 32 (largest AOT variant)."""
    assert_matches_ref(make_inputs(20080610, 32, 100, 100))
