"""AOT bridge tests: HLO text artifacts, manifest contract, golden fixture.

Lowers a (small) artifact in-process and checks the text is something the
Rust side's ``HloModuleProto::from_text_file`` can parse (starts with an
``HloModule`` header, mentions the entry computation), plus validates the
manifest and golden-fixture formats that ``rust/src/runtime`` consumes.
"""

import os

import pytest

from compile import aot, model


def test_lower_stack_emits_hlo_text():
    text = aot.lower_stack(2)
    assert text.startswith("HloModule"), text[:80]
    # return_tuple=True: the root computation returns a tuple.
    assert "ROOT" in text
    assert "f32[%d,%d]" % (model.ROI_H, model.ROI_W) in text


def test_lower_radec2xy_emits_hlo_text():
    text = aot.lower_radec2xy(16)
    assert text.startswith("HloModule")
    assert "f32[16,2]" in text


def test_golden_fixture_format():
    body = aot.golden_stack_fixture(n=2, h=8, w=8)
    lines = [l for l in body.splitlines() if l and not l.startswith("#")]
    names = [l.split("\t")[0] for l in lines]
    assert names == ["shape", "raw", "sky", "cal", "shifts", "weights", "output"]
    shape = lines[0].split("\t")[1].split()
    assert shape == ["2", "8", "8"]
    out_vals = lines[-1].split("\t")[1].split()
    assert len(out_vals) == 64


def test_main_writes_manifest(tmp_path):
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--variants", "1,2"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = (tmp_path / "manifest.tsv").read_text()
    rows = [l.split("\t") for l in manifest.splitlines() if not l.startswith("#")]
    kinds = {r[0] for r in rows}
    assert kinds == {"stack", "radec2xy"}
    for r in rows:
        assert os.path.exists(tmp_path / r[2]), r
    stack_rows = [r for r in rows if r[0] == "stack"]
    assert {r[1] for r in stack_rows} == {"stack_n1", "stack_n2"}
    # Params are key=value integers.
    assert "n=1" in stack_rows[0]
    assert (tmp_path / "golden_stack.tsv").exists()


@pytest.mark.parametrize("n", [1, 4])
def test_artifact_executes_on_cpu_pjrt(n):
    """The lowered HLO must execute (via jax on CPU) and match the oracle —
    a python-side proxy for what the Rust PJRT runtime does."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from compile.kernels.ref import stack_ref

    rng = np.random.default_rng(42)
    h, w = model.ROI_H, model.ROI_W
    raw = jnp.asarray(rng.integers(0, 4096, (n, h, w), dtype=np.int16))
    sky = jnp.asarray(rng.uniform(0, 100, (n,)).astype(np.float32))
    cal = jnp.asarray(rng.uniform(0.5, 2, (n,)).astype(np.float32))
    shifts = jnp.asarray(rng.uniform(0, 1, (n, 2)).astype(np.float32))
    weights = jnp.ones((n,), jnp.float32)
    (got,) = jax.jit(model.stack_object)(raw, sky, cal, shifts, weights)
    want = stack_ref(raw.astype(jnp.float32), sky, cal, shifts, weights)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)
