//! Configurable §4.3 micro-benchmark sweep driver.
//!
//! The figure benches run fixed sweeps; this example exposes the whole
//! 896-experiment matrix (8 configurations × read/read+write × node
//! counts × file sizes) for interactive exploration.
//!
//! Examples:
//!   cargo run --release --example microbench_sweep -- \
//!       --configs 3,5,8 --nodes 8,64 --sizes 1MB,100MB --read-write
//!   cargo run --release --example microbench_sweep -- --full

use datadiffusion::analysis::model;
use datadiffusion::config::Config;
use datadiffusion::driver::sim::SimDriver;
use datadiffusion::util::cli::{help_if_requested, Args, OptSpec};
use datadiffusion::util::units::{fmt_bps, fmt_bytes, parse_size};
use datadiffusion::workloads::microbench::{generate, MbConfig, FILE_SIZES, NODE_COUNTS};

fn config_by_number(n: u32) -> Option<MbConfig> {
    match n {
        1 => Some(MbConfig::ModelLocalDisk),
        2 => Some(MbConfig::ModelGpfs),
        3 => Some(MbConfig::FirstAvailable),
        4 => Some(MbConfig::FirstAvailableWrapper),
        5 => Some(MbConfig::FirstCacheAvail0),
        6 => Some(MbConfig::FirstCacheAvail100),
        7 => Some(MbConfig::MaxComputeUtil0),
        8 => Some(MbConfig::MaxComputeUtil100),
        _ => None,
    }
}

fn main() {
    let args = Args::from_env(&["read-write", "full", "help"]);
    let specs = [
        OptSpec { name: "configs", value: "LIST", help: "paper config numbers 1-8", default: "2,3,8" },
        OptSpec { name: "nodes", value: "LIST", help: "node counts", default: "1,8,64" },
        OptSpec { name: "sizes", value: "LIST", help: "file sizes (1B..1GB)", default: "100MB" },
        OptSpec { name: "tpn", value: "N", help: "tasks per node", default: "8" },
        OptSpec { name: "read-write", value: "", help: "read+write variant", default: "" },
        OptSpec { name: "full", value: "", help: "the full 896-cell matrix (slow)", default: "" },
    ];
    help_if_requested(&args, "microbench_sweep", "§4.3 micro-benchmark matrix", &specs);

    let full = args.flag("full");
    let rw_list: Vec<bool> = if full {
        vec![false, true]
    } else {
        vec![args.flag("read-write")]
    };
    let configs: Vec<MbConfig> = if full {
        (1..=8).filter_map(config_by_number).collect()
    } else {
        args.num_list_or("configs", &[2u32, 3, 8])
            .into_iter()
            .filter_map(config_by_number)
            .collect()
    };
    let nodes_list: Vec<usize> = if full {
        NODE_COUNTS.to_vec()
    } else {
        args.num_list_or("nodes", &[1usize, 8, 64])
    };
    let sizes: Vec<u64> = if full {
        FILE_SIZES.to_vec()
    } else {
        args.str_or("sizes", "100MB")
            .split(',')
            .map(|s| parse_size(s).unwrap_or_else(|| panic!("bad size {s:?}")))
            .collect()
    };
    let tpn: usize = args.num_or("tpn", 8);

    let mut cells = 0usize;
    println!(
        "{:<48} {:>4} {:>6} {:>10} {:>14} {:>10}",
        "config", "rw", "nodes", "size", "throughput", "tasks/s"
    );
    for &rw in &rw_list {
        for &nodes in &nodes_list {
            for &size in &sizes {
                for &mb in &configs {
                    cells += 1;
                    let (bps, rate) = match mb {
                        MbConfig::ModelLocalDisk => {
                            let cfg = Config::with_nodes(nodes);
                            let bps = if rw {
                                model::local_disk_rw_bps(&cfg, nodes, size)
                            } else {
                                model::local_disk_read_bps(&cfg, nodes, size)
                            };
                            (bps, f64::NAN)
                        }
                        MbConfig::ModelGpfs => {
                            let cfg = Config::with_nodes(nodes);
                            let bps = if rw {
                                model::gpfs_rw_bps(&cfg, nodes, size)
                            } else {
                                model::gpfs_read_bps(&cfg, nodes, size)
                            };
                            (bps, f64::NAN)
                        }
                        _ => {
                            let exp = generate(mb, nodes, size, rw, tpn);
                            let out = SimDriver::new(exp.config, exp.spec, exp.catalog).run();
                            let bps = if rw {
                                out.metrics.rw_throughput_bps()
                            } else {
                                out.metrics.read_throughput_bps()
                            };
                            (bps, out.metrics.task_rate())
                        }
                    };
                    println!(
                        "{:<48} {:>4} {:>6} {:>10} {:>14} {:>10.1}",
                        mb.label(),
                        if rw { "rw" } else { "r" },
                        nodes,
                        fmt_bytes(size),
                        fmt_bps(bps),
                        rate
                    );
                }
            }
        }
    }
    println!("\n{cells} experiment cells (paper's full matrix: 896).");
}
