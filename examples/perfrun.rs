use datadiffusion::analysis::figures::{run_stacking, StackConfig};
use datadiffusion::workloads::astro;
fn main() {
    let row = astro::row_for_locality(1.38);
    let t0 = std::time::Instant::now();
    let out = run_stacking(128, row, StackConfig::DiffusionGz, 0.3, 1);
    println!("tasks={} wall={:.2}s events={} ev/s={:.0}",
        out.metrics.tasks_done, t0.elapsed().as_secs_f64(), out.events,
        out.events as f64 / out.wall_s);
}
