//! Quickstart: an elastic live data-diffusion cluster in ~50 lines.
//!
//! Populates a tiny "persistent storage" directory with synthetic image
//! files, then runs a batch of tasks through the live coordinator with
//! the paper's default policy (max-compute-util + LRU) and the dynamic
//! resource provisioner (§3.1) *enabled*: the pool starts empty, the
//! provisioner grows it in response to queue pressure (real executor
//! threads spawn mid-run after the simulated allocation latency), and
//! data diffuses onto the newly provisioned caches.
//!
//! Run: `cargo run --release --example quickstart`

use datadiffusion::config::Config;
use datadiffusion::coordinator::task::{Task, TaskId};
use datadiffusion::driver::live::LiveCluster;
use datadiffusion::provisioner::AllocationPolicy;
use datadiffusion::storage::live::LiveStore;
use datadiffusion::storage::object::{DataFormat, ObjectId};
use datadiffusion::util::units::fmt_bytes;

fn main() -> datadiffusion::Result<()> {
    let root = std::env::temp_dir().join("dd_quickstart");
    let _ = std::fs::remove_dir_all(&root);

    // 1. "Persistent storage": 12 gzip-compressed synthetic image files.
    let mut store = LiveStore::create(root.join("gpfs"), DataFormat::Gz)?;
    for i in 0..12 {
        store.populate(ObjectId(i), 50_000)?; // 50K pixels ≈ 100KB raw
    }
    println!(
        "persistent store: {} objects, {}",
        store.catalog().len(),
        fmt_bytes(store.catalog().total_bytes())
    );

    // 2. A live cluster with data diffusion on and an ELASTIC pool: zero
    //    executors at t=0, up to 5, adaptive growth driven by the wait
    //    queue, 50 ms simulated GRAM4 allocation latency.
    let mut cfg = Config::with_nodes(5);
    cfg.provisioner.enabled = true;
    cfg.provisioner.policy = AllocationPolicy::Adaptive;
    cfg.provisioner.min_executors = 0;
    cfg.provisioner.max_executors = 5;
    cfg.provisioner.allocation_latency_s = 0.05;
    cfg.provisioner.poll_interval_s = 0.01;
    cfg.provisioner.idle_release_s = 30.0; // don't shrink mid-demo
    cfg.provisioner.queue_per_executor = 8;

    let tasks: Vec<Task> = (0..48)
        .map(|i| Task::with_inputs(TaskId(i), vec![ObjectId(i % 12)]))
        .collect();
    let out = LiveCluster::new(cfg, store, root.join("work"), None).run(tasks)?;

    let m = &out.metrics;
    println!(
        "provisioner: {} allocation requests -> {} executors joined mid-run (peak pool {})",
        m.alloc_requests, m.executors_joined, m.peak_executors
    );
    println!(
        "ran {} tasks in {:.2}s: {} local hits, {} peer fetches, {} from persistent storage",
        m.tasks_done, out.makespan_s, m.cache_hits, m.peer_hits, m.gpfs_misses
    );
    println!(
        "bytes by source: local {}, cache-to-cache {}, persistent {}",
        fmt_bytes(m.local_bytes),
        fmt_bytes(m.c2c_bytes),
        fmt_bytes(m.gpfs_bytes)
    );
    assert!(m.cache_hits + m.peer_hits > 0, "diffusion should produce hits");
    assert!(
        m.executors_joined > 0,
        "the pool started empty: every task ran on a dynamically provisioned executor"
    );
    assert!(m.peak_executors <= 5, "pool must respect max_executors");
    println!("OK: executors provisioned on demand, data diffused onto their caches and got re-used.");
    let _ = std::fs::remove_dir_all(root);
    Ok(())
}
