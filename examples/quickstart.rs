//! Quickstart: a five-node live data-diffusion cluster in ~40 lines.
//!
//! Populates a tiny "persistent storage" directory with synthetic image
//! files, runs a batch of tasks twice (cold, then warm) through the live
//! coordinator with the paper's default policy (max-compute-util + LRU),
//! and shows the cache doing its job. Also demonstrates the dynamic
//! resource provisioner making allocation decisions.
//!
//! Run: `cargo run --release --example quickstart`

use datadiffusion::config::{Config, ProvisionerConfig};
use datadiffusion::coordinator::task::{Task, TaskId};
use datadiffusion::driver::live::LiveCluster;
use datadiffusion::provisioner::{AllocationPolicy, Provisioner};
use datadiffusion::storage::live::LiveStore;
use datadiffusion::storage::object::{DataFormat, ObjectId};
use datadiffusion::util::units::fmt_bytes;

fn main() -> datadiffusion::Result<()> {
    let root = std::env::temp_dir().join("dd_quickstart");
    let _ = std::fs::remove_dir_all(&root);

    // 1. "Persistent storage": 12 gzip-compressed synthetic image files.
    let mut store = LiveStore::create(root.join("gpfs"), DataFormat::Gz)?;
    for i in 0..12 {
        store.populate(ObjectId(i), 50_000)?; // 50K pixels ≈ 100KB raw
    }
    println!(
        "persistent store: {} objects, {}",
        store.catalog().len(),
        fmt_bytes(store.catalog().total_bytes())
    );

    // 2. The dynamic resource provisioner decides how many executors the
    //    queued work justifies (§3.1). 36 queued tasks / 4-per-executor
    //    target -> 9, capped at the 5-node cluster.
    let mut drp = Provisioner::new(ProvisionerConfig {
        policy: AllocationPolicy::Adaptive,
        max_executors: 5,
        ..ProvisionerConfig::default()
    });
    let actions = drp.evaluate(36, 0.0);
    println!("provisioner: queue=36 -> {actions:?}");

    // 3. A live cluster with data diffusion on.
    let cfg = Config::with_nodes(5);
    let tasks: Vec<Task> = (0..36)
        .map(|i| Task::with_inputs(TaskId(i), vec![ObjectId(i % 12)]))
        .collect();
    let out = LiveCluster::new(cfg, store, root.join("work"), None).run(tasks)?;

    let m = &out.metrics;
    println!(
        "ran {} tasks in {:.2}s: {} local hits, {} peer fetches, {} from persistent storage",
        m.tasks_done, out.makespan_s, m.cache_hits, m.peer_hits, m.gpfs_misses
    );
    println!(
        "bytes by source: local {}, cache-to-cache {}, persistent {}",
        fmt_bytes(m.local_bytes),
        fmt_bytes(m.c2c_bytes),
        fmt_bytes(m.gpfs_bytes)
    );
    assert!(m.cache_hits + m.peer_hits > 0, "diffusion should produce hits");
    println!("OK: data diffused onto executor caches and got re-used.");
    let _ = std::fs::remove_dir_all(root);
    Ok(())
}
