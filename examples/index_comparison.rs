//! Figure 2 interactive driver: our measured centralized index vs the
//! P-RLS analytic model, with adjustable index size.
//!
//! Run: `cargo run --release --example index_comparison -- --entries 4000000`

use datadiffusion::index::central::CentralIndex;
use datadiffusion::index::prls::{PrlsModel, MEASURED};
use datadiffusion::storage::object::ObjectId;
use datadiffusion::util::bench::black_box;
use datadiffusion::util::cli::{help_if_requested, Args, OptSpec};
use std::time::Instant;

fn main() {
    let args = Args::from_env(&["help"]);
    let specs = [OptSpec {
        name: "entries",
        value: "N",
        help: "index size (paper studies 1M-8M)",
        default: "1000000",
    }];
    help_if_requested(&args, "index_comparison", "Fig 2: central index vs P-RLS", &specs);
    let entries: u64 = args.num_or("entries", 1_000_000);

    println!("building a {entries}-entry centralized index...");
    let mut idx = CentralIndex::new();
    let t0 = Instant::now();
    for i in 0..entries {
        idx.insert(ObjectId(i), (i % 128) as usize);
    }
    let insert_total = t0.elapsed().as_secs_f64();
    println!(
        "inserts: {:.2}s total, {:.3} us/op (paper: 1-3 us at 1M-8M entries)",
        insert_total,
        insert_total / entries as f64 * 1e6
    );

    let lookups = entries.min(4_000_000);
    let mut acc = 0usize;
    let t0 = Instant::now();
    for i in 0..lookups {
        acc += black_box(idx.locations(ObjectId((i * 6_364_136_223_846_793_005u64.wrapping_add(7)) % entries)).len());
    }
    black_box(acc);
    let per = t0.elapsed().as_secs_f64() / lookups as f64;
    let rate = 1.0 / per;
    println!(
        "lookups: {:.3} us/op -> {:.3e} lookups/s (paper: 0.25-1 us, ~4.18e6/s)",
        per * 1e6,
        rate
    );

    let model = PrlsModel::fit();
    println!("\nChervenak et al. measured P-RLS points (nodes, latency):");
    for (n, lat) in MEASURED.iter().step_by(4) {
        println!("  {n:>3} nodes: {:.2} ms", lat * 1e3);
    }
    println!(
        "log fit: latency(n) = {:.3}ms + {:.3}ms*ln(n)",
        model.a * 1e3,
        model.b * 1e3
    );
    println!("\n{:>10} {:>16} {:>20}", "nodes", "P-RLS latency", "P-RLS agg lookups/s");
    let mut n = 1u64;
    while n <= 1 << 20 {
        println!(
            "{n:>10} {:>14.2}ms {:>20.3e}",
            model.latency(n) * 1e3,
            model.aggregate_throughput(n)
        );
        n <<= 2;
    }
    match model.crossover_nodes(rate) {
        Some(x) => println!(
            "\nP-RLS needs {x} nodes to match this one-node index (paper: >32K nodes). \
             Conclusion: a centralized index is the right call at Falkon's scale."
        ),
        None => println!("\nP-RLS never catches up within 2^30 nodes."),
    }
}
