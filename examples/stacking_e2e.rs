//! END-TO-END driver: the paper's astronomy image-stacking application on
//! the full three-layer stack, on a real (small) workload.
//!
//! * Layer 3: the Rust coordinator (this process) — dispatch, caching,
//!   peer transfers, metrics — over a live mini-cluster of executor
//!   threads and real files (gzip-compressed synthetic sky images).
//! * Layer 2/1: the JAX/Pallas stacking model, AOT-compiled to
//!   `artifacts/*.hlo.txt` by `make artifacts`, executed through PJRT on
//!   the request path. Python is NOT involved at runtime.
//!
//! The run sweeps data locality (Table 2 style) and compares data
//! diffusion against the GPFS-only baseline on the paper's headline
//! metrics: cache-hit ratio vs ideal, bytes by source, time per stack.
//! Numerics are verified against the pure-jnp oracle via the golden
//! fixture (`artifacts/golden_stack.tsv`).
//!
//! Run: `make artifacts && cargo run --release --example stacking_e2e`
//! Flags: `--profile` prints the Fig 7-style phase breakdown;
//!        `--tasks N --objects N --nodes N` resize the workload.

use datadiffusion::config::Config;
use datadiffusion::coordinator::task::{Task, TaskId};
use datadiffusion::driver::live::LiveCluster;
use datadiffusion::runtime::{artifacts_dir, PjrtEngine, StackRequest};
use datadiffusion::scheduler::DispatchPolicy;
use datadiffusion::storage::live::LiveStore;
use datadiffusion::storage::object::{DataFormat, ObjectId};
use datadiffusion::util::cli::Args;
use datadiffusion::util::units::{fmt_bytes, fmt_secs};
use datadiffusion::workloads::astro;
use std::time::Instant;

fn verify_golden(engine: &PjrtEngine) -> datadiffusion::Result<f64> {
    // The golden fixture pins the PJRT execution to the pure-jnp oracle:
    // inputs and the reference output were produced at AOT time.
    let path = artifacts_dir().join("golden_stack.tsv");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| datadiffusion::Error::Artifact(format!("{}: {e}", path.display())))?;
    let mut fields = std::collections::HashMap::new();
    let mut shape = (0usize, 0usize, 0usize);
    for line in text.lines() {
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        let (name, rest) = line.split_once('\t').expect("golden format");
        if name == "shape" {
            let v: Vec<usize> = rest
                .split_whitespace()
                .map(|s| s.parse().unwrap())
                .collect();
            shape = (v[0], v[1], v[2]);
        } else {
            let vals: Vec<f64> = rest
                .split_whitespace()
                .map(|s| s.parse().unwrap())
                .collect();
            fields.insert(name.to_string(), vals);
        }
    }
    let (n, h, w) = shape;
    let req = StackRequest {
        raw: fields["raw"].iter().map(|&v| v as i16).collect(),
        sky: fields["sky"].iter().map(|&v| v as f32).collect(),
        cal: fields["cal"].iter().map(|&v| v as f32).collect(),
        shifts: fields["shifts"].iter().map(|&v| v as f32).collect(),
        weights: fields["weights"].iter().map(|&v| v as f32).collect(),
        depth: n,
    };
    let out = engine.stack(&req)?;
    let expect = &fields["output"];
    assert_eq!(out.len(), h * w);
    let mut max_err = 0.0f64;
    for (a, b) in out.iter().zip(expect) {
        max_err = max_err.max((*a as f64 - b).abs());
    }
    Ok(max_err)
}

fn profile_phases(engine: &PjrtEngine) {
    // Fig 7-style phase breakdown on 1 CPU: I/O phases (open/read) are
    // owned by the executor; compute phases run through PJRT.
    println!("\n--- Fig 7-style profile (1 CPU, 1000 objects, 100x100 ROIs) ---");
    let (h, w) = engine.roi_shape();
    let depth = 8usize;
    let mut io_s = 0.0;
    let mut compute_s = 0.0;
    let dir = std::env::temp_dir().join("dd_e2e_profile");
    let _ = std::fs::remove_dir_all(&dir);
    let mut store = LiveStore::create(&dir, DataFormat::Gz).expect("store");
    for i in 0..50 {
        store.populate(ObjectId(i), h * w).expect("populate");
    }
    let runs = 1000;
    for i in 0..runs {
        let obj = ObjectId(i % 50);
        let t0 = Instant::now();
        let raw = store.read(obj).expect("read");
        let pixels = datadiffusion::storage::live::pixels_of(&raw);
        io_s += t0.elapsed().as_secs_f64();
        let (raw_px, sky, cal, shifts, weights) =
            datadiffusion::workloads::sky::stack_inputs(obj, &pixels, depth, h, w);
        let t1 = Instant::now();
        let _ = engine
            .stack(&StackRequest {
                raw: raw_px,
                sky,
                cal,
                shifts,
                weights,
                depth,
            })
            .expect("stack");
        compute_s += t1.elapsed().as_secs_f64();
    }
    println!(
        "open+readHDU+getTile (I/O+gunzip): {:.3} ms/task",
        io_s / runs as f64 * 1e3
    );
    println!(
        "calibration+interpolation+doStacking (PJRT): {:.3} ms/task",
        compute_s / runs as f64 * 1e3
    );
    println!("paper: I/O dominates; compute <1 ms + radec2xy 10-20%");
    let _ = std::fs::remove_dir_all(dir);
}

fn main() -> datadiffusion::Result<()> {
    let args = Args::from_env(&["profile", "help"]);
    let n_nodes: usize = args.num_or("nodes", 4);
    let n_objects: u64 = args.num_or("objects", 24);
    let n_tasks: u64 = args.num_or("tasks", 240);

    println!("=== stacking end-to-end: Rust coordinator + PJRT(JAX/Pallas AOT) ===");
    let engine = PjrtEngine::load_default()?;
    println!(
        "PJRT: platform={}, stack variants n={:?}, ROI {:?}",
        engine.platform(),
        engine.stack_depths(),
        engine.roi_shape()
    );

    // Numerics gate: PJRT output vs the pure-jnp oracle.
    let max_err = verify_golden(&engine)?;
    println!("golden check: max |pjrt - oracle| = {max_err:.2e} (gate: < 1e-2 of pixel scale)");
    assert!(max_err < 1e-2, "PJRT numerics diverged from the oracle");

    if args.flag("profile") {
        profile_phases(&engine);
    }

    // Locality sweep: same task count, varying objects-per-file re-use.
    let (h, w) = engine.roi_shape();
    let root = std::env::temp_dir().join("dd_e2e");
    println!(
        "\n{:>9} {:>10} {:>8} {:>8} {:>8} {:>9} {:>11} {:>11} {:>11}",
        "workload", "time/task", "hit%", "ideal%", "c2c", "gpfs", "local B", "c2c B", "gpfs B"
    );
    for &locality in &[1u64, 3, 8, 30] {
        for caching in [true, false] {
            let files = (n_tasks / locality).clamp(1, n_objects);
            let _ = std::fs::remove_dir_all(&root);
            let mut store = LiveStore::create(root.join("gpfs"), DataFormat::Gz)?;
            for i in 0..files {
                store.populate(ObjectId(i), h * w)?;
            }
            let mut cfg = Config::with_nodes(n_nodes);
            cfg.scheduler.policy = if caching {
                DispatchPolicy::MaxComputeUtil
            } else {
                DispatchPolicy::FirstAvailable
            };
            let depth = locality.min(32) as u32;
            let tasks: Vec<Task> = (0..n_tasks)
                .map(|i| Task::stacking(TaskId(i), ObjectId(i % files), depth, 0))
                .collect();
            let out =
                LiveCluster::new(cfg, store, root.join("work"), Some(artifacts_dir())).run(tasks)?;
            let m = &out.metrics;
            let label = if caching {
                format!("DD L={locality}")
            } else {
                format!("GPFS L={locality}")
            };
            println!(
                "{label:>9} {:>10} {:>7.1}% {:>7.1}% {:>8} {:>9} {:>11} {:>11} {:>11}",
                fmt_secs(out.makespan_s / m.tasks_done.max(1) as f64),
                m.local_hit_ratio() * 100.0,
                astro::ideal_hit_ratio(locality as f64) * 100.0,
                m.peer_hits,
                m.gpfs_misses,
                fmt_bytes(m.local_bytes),
                fmt_bytes(m.c2c_bytes),
                fmt_bytes(m.gpfs_bytes),
            );
        }
    }
    println!(
        "\nheadline: with locality, data diffusion serves inputs from executor caches\n\
         (hit%% -> ideal%%) and the load on persistent storage collapses, while the\n\
         GPFS baseline re-reads every byte — the paper's scaling argument, live,\n\
         with real PJRT stacking numerics verified against the JAX oracle."
    );
    let _ = std::fs::remove_dir_all(root);
    Ok(())
}
